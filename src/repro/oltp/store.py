"""In-memory row stores behind one batched-first protocol (paper §6.1/§7).

Every store implements the :class:`RowStore` protocol (DESIGN.md §3) —
``insert_many / get_many / update_many / delete_many / scan / stats()`` over
a dense primary-key id space, with scalar ``insert/get/update/delete`` kept
as thin wrappers — so every harness and benchmark drives one interface.
Compressors:

* ``BlitzStore``      — TableCodec (semantic models + delayed coding) over
                        the CSR code arena, with a bounded delta overlay and
                        Funke-style ``merge()`` compaction back into the arena
* ``ZstdStore``       — per-tuple zstd with a trained dictionary (the
                        paper's Zstandard baseline, §6 "training mode")
* ``RamanStore``      — per-column canonical Huffman, concatenated
                        variable-length tuples (static dictionary: unseen
                        values need an escape)
* ``UncompressedStore`` — Silo-style plain rows

Plus the §6.5 fast path: :class:`LRUFastPath`, an LRU write-back cache of
decompressed tuples that also speaks the protocol.

Deletion semantics (uniform across stores): ids are never reused;
``get_many`` returns ``None`` for tombstoned ids, scalar ``get`` raises
``KeyError``, updating a deleted row raises ``KeyError``, repeat deletes
are no-ops.
"""

from __future__ import annotations

import contextlib
import json
from collections import OrderedDict
from types import MappingProxyType
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro import sanitize, telemetry
from repro.adaptive import MaintenanceConfig, MaintenanceScheduler
from repro.core import ColumnSpec, TableCodec
from repro.core.arena import (
    ExtentCorruptionError, ResidencyManager, SpillCorruptionError, framed_len
)
from repro.core.blitzcrank import CompressedTable, _raw_row_bytes, column_specs
from repro.core.huffman import BitReader, BitWriter, HuffmanCode

# Per-entry charge of an uncompressed dict overlay / cache slot: 8 B key +
# 8 B table-slot pointer on top of the raw row bytes (DESIGN.md §3).
OVERLAY_ENTRY_OVERHEAD = 16
# A pending tombstone is one id in a hash set.
TOMBSTONE_BYTES = 8

# Telemetry handles (DESIGN.md §9): delta-merge and the byte-store's
# cold-tier spill/fault-in, which shares phase prefixes with the
# CompressedTable block paths.
_H_MERGE = telemetry.histogram("repro.store.merge")
_C_MERGES = telemetry.counter("repro.store.merge.events")
_C_OVERLAY_HITS = telemetry.counter("repro.store.overlay.hits")
_H_ROW_FAULT = telemetry.histogram("repro.residency.fault_in.rows")
_H_ROW_SPILL = telemetry.histogram("repro.residency.spill.rows")
_C_ROW_FAULTS = telemetry.counter("repro.residency.fault_in.rows.count")
_C_ROW_SPILLS = telemetry.counter("repro.residency.spill.rows.count")


class RowStore:
    """Unified batched-first storage protocol (DESIGN.md §3).

    Subclasses implement the batched methods; the scalar ``insert / get /
    update / delete`` are thin wrappers over them.  ``len(store)`` is the
    id span (including tombstones), ``n_live`` the live row count.
    ``schema`` may be a plain sequence of :class:`ColumnSpec` or any object
    with a ``.columns`` attribute (:class:`repro.db.TableSchema`).

    Return conventions and tombstone semantics (the protocol contract —
    every store and wrapper must match it bit for bit):

    * ``insert_many(rows) -> range`` — the dense ids assigned, in row
      order; ``insert(row) -> int`` is the single id.  Ids are assigned
      contiguously from the current span and **never reused**, even after
      deletion.
    * ``get_many(ids) -> list`` — one entry per requested id, in request
      order; tombstoned ids yield ``None`` (a read-side abort signal, not
      an error).  Scalar ``get(id)`` raises ``KeyError`` instead, and
      ``IndexError`` semantics for never-assigned ids follow the backing
      container.
    * ``update_many(ids, rows) -> None`` — in-place overwrite, duplicate
      ids deduplicated last-write-wins *before* hitting storage; updating
      a tombstoned id raises ``KeyError``.  ``update`` is the 1-element
      wrapper.
    * ``delete_many(ids) -> int`` — the number of rows that transitioned
      live→tombstoned (repeats and already-dead ids are no-ops, so the
      count is of *effective* deletes); ``delete(id) -> bool`` — whether
      this call performed the delete.  Both are idempotent.
    * ``scan() -> iterator of (id, row)`` — live rows only, id order.
    """

    name = "rowstore"

    def __init__(
        self, schema: Optional[Sequence[ColumnSpec]] = None
    ) -> None:
        self.schema = column_specs(schema) if schema is not None else None

    # -- batched protocol (override) -------------------------------------
    def insert_many(self, rows: Sequence[Dict[str, Any]]) -> range:
        raise NotImplementedError

    def get_many(
        self, indices: Sequence[int], backend: Optional[str] = None
    ) -> List[Optional[Dict[str, Any]]]:
        # ``backend`` selects the decode backend where one exists
        # (BlitzStore); every store accepts it so callers need no
        # isinstance checks (DESIGN.md §8 unified verb signatures).
        raise NotImplementedError

    def update_many(
        self, indices: Sequence[int], rows: Sequence[Dict[str, Any]]
    ) -> None:
        raise NotImplementedError

    def delete_many(self, indices: Sequence[int]) -> int:
        raise NotImplementedError

    def scan(
        self, start: int = 0, stop: Optional[int] = None, batch: int = 1024
    ) -> Iterator[Tuple[int, Dict[str, Any]]]:
        """Yield ``(id, row)`` for live rows in id order, a batch at a time."""
        n = len(self)
        stop = n if stop is None else min(stop, n)
        for lo in range(start, stop, batch):
            ids = range(lo, min(lo + batch, stop))
            # blitzlint: waive[BL001] -- scan generator yields per-row dicts; get_many batches the decode underneath
            for i, r in zip(ids, self.get_many(ids)):
                if r is not None:
                    yield i, r

    def scan_where(
        self,
        predicates: Sequence[Any],
        columns: Optional[Sequence[str]] = None,
        pushdown: bool = True,
        backend: Optional[str] = None,
    ) -> "Any":
        """Filtered scan -> :class:`repro.scan.ScanResult` (ids ascending).

        The base implementation is the decode-everything reference:
        decode every live row through :meth:`scan`, filter in value space,
        project.  Stores with a pushdown path override this;
        ``pushdown=False`` forces the reference everywhere (the
        comparability baseline in ``bench_htap``).
        """
        from repro.scan import ScanResult, ScanStats, match_all
        preds = list(predicates)
        ids: List[int] = []
        rows: List[Dict[str, Any]] = []
        stats = ScanStats()
        for i, r in self.scan():
            stats.rows_decoded += 1
            if match_all(preds, r):
                ids.append(i)
                rows.append(r if columns is None else {c: r[c] for c in columns})
        stats.rows_matched = len(ids)
        return ScanResult(ids, rows, stats)

    # Registry prefixes a store-level stats() view reports: encode/decode
    # kernels, plan cache, residency, delta merge — not db/scan/wal, which
    # belong to the table- and engine-level sections (DESIGN.md §9).
    TELEMETRY_PREFIXES = ("repro.core.", "repro.plan.",
                          "repro.residency.", "repro.store.")

    def stats(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "n_ids": len(self),
            "n_live": self.n_live,
            "n_deleted": len(self) - self.n_live,
            "nbytes": self.nbytes,
            "model_bytes": getattr(self, "model_bytes", 0),
            "telemetry": telemetry.snapshot(prefix=self.TELEMETRY_PREFIXES),
        }

    # -- scalar wrappers -------------------------------------------------
    def insert(self, row: Dict[str, Any]) -> int:
        return self.insert_many([row])[0]

    def get(self, i: int) -> Dict[str, Any]:
        r = self.get_many([int(i)])[0]
        if r is None:
            raise KeyError(f"row {int(i)} is deleted")
        return r

    def update(self, i: int, row: Dict[str, Any]) -> None:
        self.update_many([int(i)], [row])

    def delete(self, i: int) -> bool:
        """True when this call deleted a live row (already-dead: False)."""
        return self.delete_many([int(i)]) == 1

    # -- shared helpers --------------------------------------------------
    def is_live(self, i: int) -> bool:
        """True when id ``i`` exists and is not tombstoned (per-store state)."""
        raise NotImplementedError

    @property
    def n_live(self) -> int:
        raise NotImplementedError

    def __len__(self) -> int:
        raise NotImplementedError

    @property
    def nbytes(self) -> int:
        raise NotImplementedError

    @staticmethod
    def _dedup_last(
        indices: Sequence[int], rows: Sequence[Dict[str, Any]]
    ) -> Tuple[List[int], List[Dict[str, Any]]]:
        """Unique (id, row) pairs, last write wins (update_many contract)."""
        m: Dict[int, Dict[str, Any]] = {}
        # blitzlint: waive[BL001] -- last-write-wins dedup is one ordered pass over the update batch
        for i, r in zip(indices, rows):
            m[int(i)] = r
        return list(m.keys()), list(m.values())


class _BytesRowStore(RowStore):
    """Shared list-of-encoded-tuples plumbing for the baseline stores:
    one encoded payload per id, tombstones in a side set.

    ``memory_budget`` enables the same out-of-core cold tier the blitz
    store has (paper §6.4, DESIGN.md §6), at tuple granularity: when the
    resident payload bytes exceed the budget, a clock/second-chance sweep
    over per-row referenced bits spills cold payloads to a
    :class:`~repro.core.arena.DiskArena` (``rows[i] = None`` + an extent
    in ``_spilled``); reads fault them back in with one coalesced read per
    batch.  This is what makes "the uncompressed store at the same
    absolute budget" a fair baseline in ``bench_out_of_core``.
    """

    # Per spilled row: 8 B offset + 4 B length + clock bit, rounded up.
    SPILL_ENTRY_OVERHEAD = 13

    def __init__(
        self,
        schema: Sequence[ColumnSpec],
        memory_budget: Optional[int] = None,
        spill_path: Optional[str] = None,
        spill_io: Optional[Any] = None,
    ):
        super().__init__(schema)
        self.rows: List[Optional[bytes]] = []
        self._deleted: set = set()
        self._res: Optional[ResidencyManager] = None
        self._spilled: Dict[int, Tuple[int, int]] = {}  # id -> (off, len)
        self._ref = bytearray()  # clock bits; hand lives in the manager
        self._resident_bytes = 0
        self._spilled_payload = 0
        # Durability hook (DESIGN.md §7): rebuilds rows from the WAL when a
        # spilled extent fails its CRC check.  Installed by repro.db.Table
        # on durable databases; without it corruption propagates as
        # SpillCorruptionError (never as garbage rows).
        self.repair_fn: Optional[Callable] = None
        self.repairs = 0
        if memory_budget is not None:
            self._res = ResidencyManager(memory_budget, spill_path, io=spill_io)

    def is_live(self, i: int) -> bool:
        i = int(i)
        return 0 <= i < len(self.rows) and i not in self._deleted

    @property
    def n_live(self) -> int:
        return len(self.rows) - len(self._deleted)

    def _encode_row(self, row: Dict[str, Any]) -> bytes:
        raise NotImplementedError

    def _decode_row(self, raw: bytes) -> Dict[str, Any]:
        raise NotImplementedError

    # -- cold tier -------------------------------------------------------
    def _append_payloads(self, payloads: List[bytes]) -> range:
        base = len(self.rows)
        self.rows.extend(payloads)
        if self._res is not None:
            self._ref.extend(b"\x01" * len(payloads))
            self._resident_bytes += sum(len(p) for p in payloads)
            self._enforce_budget()
        return range(base, len(self.rows))

    def _put_payload(self, i: int, payload: bytes) -> None:
        """Overwrite row ``i``'s payload, keeping residency accounting."""
        old = self.rows[i]
        if old is None:  # spilled: the old extent is simply dropped
            off, ln = self._spilled.pop(i)
            self._res.disk.free(off, framed_len(ln))
            self._spilled_payload -= ln
        elif self._res is not None:
            self._resident_bytes -= len(old)
        self.rows[i] = payload
        if self._res is not None:
            self._resident_bytes += len(payload)
            self._ref[i] = 1

    def _fetch_payloads(self, indices: Sequence[int]) -> List[Optional[bytes]]:
        """Payload per id (``None`` for tombstones), faulting spilled rows
        back in with one coalesced disk read for the whole batch."""
        dels, rows = self._deleted, self.rows
        out: List[Optional[bytes]] = [None] * len(indices)
        cold: List[int] = []
        for j, i in enumerate(indices):
            if i in dels:
                continue
            p = rows[i]
            if p is None:
                cold.append(i)
            else:
                out[j] = p
        if cold:
            t0 = telemetry.clock()
            res = self._res
            ids = sorted(set(cold))
            for _attempt in range(3):
                extents = [self._spilled[i] for i in ids]
                try:
                    payloads = res.disk.read_many_checked(
                        [e[0] for e in extents], [e[1] for e in extents]
                    )
                    break
                except ExtentCorruptionError as e:
                    # Quarantine the bad extents and rebuild their rows
                    # from the WAL (repair_fn); repaired rows come back
                    # resident, the rest retry the checked read.
                    bad = [ids[j] for j in e.indices]
                    res.quarantined += len(bad)
                    self._repair_rows(bad)
                    ids = [i for i in ids if i in self._spilled]
                    payloads = []
            else:
                raise SpillCorruptionError(ids)
            # blitzlint: waive[BL001] -- crash-replay fault bookkeeping frees per-row extents on the cold repair path
            for i, p in zip(ids, payloads):
                off, ln = self._spilled.pop(i)
                rows[i] = p
                res.disk.free(off, framed_len(ln))
                self._resident_bytes += ln
                self._spilled_payload -= ln
                self._ref[i] = 1
            if ids:
                res.faults += len(ids)
                res.fault_batches += 1
                _C_ROW_FAULTS.add(len(ids))
            for j, i in enumerate(indices):
                if out[j] is None and i not in dels:
                    out[j] = rows[i]
            self._enforce_budget()
            _H_ROW_FAULT.observe_since(t0)
        if self._res is not None:
            for i in indices:
                if i not in dels:
                    self._ref[i] = 1
        return out

    def _enforce_budget(self) -> None:
        res = self._res
        if res is None:
            return
        if self._resident_bytes > res.budget:
            target = int(res.config.low_water * res.budget)
            rows, dels = self.rows, self._deleted

            def candidates(ids: np.ndarray) -> np.ndarray:
                # resident live payloads only (None=spilled, b""=deleted)
                return np.fromiter(
                    (bool(rows[i]) and i not in dels
                     for i in ids.tolist()),
                    dtype=bool, count=ids.size)

            def sizes(ids: np.ndarray) -> np.ndarray:
                return np.fromiter(
                    (len(rows[i]) for i in ids.tolist()), dtype=np.int64, count=ids.size
                )

            # a zero-copy numpy view over the bytearray of clock bits
            ref = np.frombuffer(self._ref, dtype=np.uint8)
            victims = res.sweep(
                len(rows), self._resident_bytes - target, candidates,
                sizes, lambda ids: ref[ids] != 0,
                lambda ids: ref.__setitem__(ids, 0))
            if victims.size:
                self._spill_rows(victims.tolist())
        # checked even when under budget: deletes/updates free extents
        # without spilling, and the file must still shrink
        if res.disk.needs_compact and self._spilled:
            ids = list(self._spilled)
            new_offs = res.disk.compact(
                [self._spilled[i][0] for i in ids],
                [framed_len(self._spilled[i][1]) for i in ids],
            )
            # blitzlint: waive[BL001] -- disk-compaction remap rewrites per-row extent directory entries (cold path)
            for i, off in zip(ids, new_offs):
                self._spilled[i] = (off, self._spilled[i][1])

    def _spill_rows(self, ids: List[int]) -> None:
        """One coalesced segment write (CRC32-framed extents) for the
        whole victim set."""
        t0 = telemetry.clock()
        res = self._res
        payloads = [self.rows[i] for i in ids]
        offs = res.disk.write_many(payloads)
        # blitzlint: waive[BL001] -- per-row extent directory update after one coalesced segment write
        for i, off, p in zip(ids, offs, payloads):
            ln = len(p)
            self._spilled[i] = (off, ln)
            self.rows[i] = None
            self._resident_bytes -= ln
            self._spilled_payload += ln
        res.spills += len(ids)
        _C_ROW_SPILLS.add(len(ids))
        _H_ROW_SPILL.observe_since(t0)

    def _repair_rows(self, ids: List[int]) -> None:
        """Rebuild corrupt spilled rows from the WAL via ``repair_fn``.

        Rebuilt rows are re-encoded resident (their corrupt extents are
        freed); ids the WAL cannot resolve to a live row are tombstoned —
        their latest logical state is "deleted", and garbage is never
        served.  Without a repair handler the corruption propagates."""
        if self.repair_fn is None:
            raise SpillCorruptionError(ids)
        fetched = self.repair_fn(list(ids))
        # blitzlint: waive[BL001] -- WAL-driven repair is the cold corruption path, not the OLTP fast path
        for i, row in zip(ids, fetched):
            if row is None:
                off, ln = self._spilled.pop(i)
                self._res.disk.free(off, framed_len(ln))
                self._spilled_payload -= ln
                self.rows[i] = b""
                self._ref[i] = 0
                self._deleted.add(i)
            else:
                self._put_payload(i, self._encode_row(row))
        self.repairs += len(ids)
        self._res.repaired_rows += len(ids)

    # -- batched protocol ------------------------------------------------
    def insert_many(self, rows: Sequence[Dict[str, Any]]) -> range:
        enc = self._encode_row
        return self._append_payloads([enc(r) for r in rows])

    def get_many(
        self, indices: Sequence[int], backend: Optional[str] = None
    ) -> List[Optional[Dict[str, Any]]]:
        idxs = [int(j) for j in indices]
        dec = self._decode_row
        if self._res is None:
            dels, rows = self._deleted, self.rows
            return [None if i in dels else dec(rows[i]) for i in idxs]
        return [None if p is None else dec(p) for p in self._fetch_payloads(idxs)]

    def update_many(
        self, indices: Sequence[int], rows: Sequence[Dict[str, Any]]
    ) -> None:
        idxs, rows = self._dedup_last(indices, rows)
        # blitzlint: waive[BL001] -- uncompressed silo baseline stores row dicts; per-row put is its contract
        for i, r in zip(idxs, rows):
            if not self.is_live(i):
                raise KeyError(f"row {i} is deleted")
            self._put_payload(i, self._encode_row(r))
        if self._res is not None:
            self._enforce_budget()

    def delete_many(self, indices: Sequence[int]) -> int:
        n = 0
        for i in {int(j) for j in indices}:
            if self.is_live(i):
                self._put_payload(i, b"")  # reclaim the tuple bytes
                self._deleted.add(i)
                n += 1
        if n and self._res is not None:
            self._enforce_budget()  # freed extents may warrant a compact
        return n

    def __len__(self) -> int:
        return len(self.rows)

    @property
    def nbytes(self) -> int:
        """Resident footprint: spilled payloads live on disk and are
        excluded; each spilled row is charged its extent-index entry."""
        if self._res is None:
            return (
                sum(len(r) for r in self.rows) + TOMBSTONE_BYTES * len(self._deleted)
            )
        return (self._resident_bytes
                + self.SPILL_ENTRY_OVERHEAD * len(self._spilled)
                + TOMBSTONE_BYTES * len(self._deleted))

    @property
    def spilled_bytes(self) -> int:
        return self._spilled_payload

    def stats(self) -> Dict[str, Any]:
        out = super().stats()
        if self.repairs:
            out["repairs"] = self.repairs
        if self._res is not None:
            out["spilled_bytes"] = self.spilled_bytes
            out["residency"] = {
                **self._res.stats(),
                "resident_bytes": self.nbytes,
                "spilled_bytes": self.spilled_bytes,
                "spilled_rows": len(self._spilled),
            }
        return out

    # -- durability (DESIGN.md §7) ---------------------------------------
    def close(self, unlink: bool = False) -> None:
        if self._res is not None:
            self._res.close(unlink=unlink)

    def _snapshot_model(self) -> Any:
        """Subclass hook: per-store model state (dict/codes) to pickle."""
        return None

    def _restore_model(self, state: Any) -> None:
        pass

    def snapshot_state(self) -> Dict[str, Any]:
        """Self-contained state: spilled payloads are read back
        (CRC-verified, repaired from the WAL on mismatch) and embedded."""
        st: Dict[str, Any] = {
            "model": self._snapshot_model(),
        }
        if self._res is not None:
            ids = sorted(self._spilled)
            for _attempt in range(3):
                extents = [self._spilled[i] for i in ids]
                try:
                    payloads = self._res.disk.read_many_checked(
                        [e[0] for e in extents], [e[1] for e in extents]
                    )
                    break
                except ExtentCorruptionError as e:
                    bad = [ids[j] for j in e.indices]
                    self._res.quarantined += len(bad)
                    self._repair_rows(bad)
                    ids = [i for i in ids if i in self._spilled]
                    payloads = []
            else:
                raise SpillCorruptionError(ids)
            st["residency"] = {
                "budget": self._res.budget,
                "config": self._res.config,
                "ref": bytes(self._ref),
                "spilled": dict(zip(ids, payloads)),
            }
        # after any repairs above so repaired rows snapshot resident
        st["rows"] = list(self.rows)
        st["deleted"] = sorted(self._deleted)
        return st

    @classmethod
    def from_state(
        cls,
        schema: Sequence[ColumnSpec],
        state: Dict[str, Any],
        spill_path: Optional[str] = None,
        spill_io: Optional[Any] = None,
    ) -> "_BytesRowStore":
        """Rebuild from :meth:`snapshot_state`; previously spilled rows are
        re-spilled into a fresh spill file, preserving the residency
        split."""
        self = cls.__new__(cls)
        RowStore.__init__(self, schema)
        self.rows = list(state["rows"])
        self._deleted = set(state["deleted"])
        self._res = None
        self._spilled = {}
        self._ref = bytearray()
        self._resident_bytes = 0
        self._spilled_payload = 0
        self.repair_fn = None
        self.repairs = 0
        self._restore_model(state["model"])
        res_state = state.get("residency")
        if res_state is not None:
            self._res = ResidencyManager(
                res_state["budget"], spill_path, res_state.get("config"), io=spill_io
            )
            self._ref = bytearray(res_state["ref"])
            self._resident_bytes = sum(len(r) for r in self.rows if r is not None)
            sp = res_state["spilled"]
            ids = sorted(sp)
            if ids:
                offs = self._res.disk.write_many([sp[i] for i in ids])
                # blitzlint: waive[BL001] -- snapshot respill rebuilds the per-row extent directory on reopen (cold path)
                for i, off in zip(ids, offs):
                    ln = len(sp[i])
                    self._spilled[i] = (off, ln)
                    self._spilled_payload += ln
        return self


class UncompressedStore(_BytesRowStore):
    name = "silo"

    def __init__(
        self,
        schema: Sequence[ColumnSpec],
        rows_sample=None,
        memory_budget: Optional[int] = None,
        spill_path: Optional[str] = None,
        spill_io: Optional[Any] = None,
    ):
        super().__init__(
            schema,
            memory_budget=memory_budget,
            spill_path=spill_path,
            spill_io=spill_io,
        )

    def _encode_row(self, row: Dict[str, Any]) -> bytes:
        return json.dumps([row[c.name] for c in self.schema]).encode()

    def _decode_row(self, raw: bytes) -> Dict[str, Any]:
        return {c.name: v for c, v in zip(self.schema, json.loads(raw))}


class BlitzStore(RowStore):
    """TableCodec store: CSR code arena + bounded delta overlay (§2.5/§3).

    Cold rows live in a :class:`CompressedTable`; batched point reads
    (:meth:`get_many`) decode through ``decode_select`` with no per-tuple
    Python loop whenever the codec compiled.  Updates and deletes go to an
    uncompressed delta overlay / tombstone set consulted before the arena.
    The overlay is *bounded*: when it exceeds ``merge_frac`` of the arena
    code bytes (min ``merge_min_bytes``), :meth:`merge` re-encodes the dirty
    rows through the bulk ``encode_batch`` path back into the arena
    (``CompressedTable.replace_many``), applies tombstones, and rewrites the
    arena once dead bytes pass ``rewrite_frac`` — so a write-heavy run stays
    compressed instead of converging to raw size (DESIGN.md §3).

    ``adaptive`` (DESIGN.md §4) turns on model maintenance: a
    :class:`~repro.adaptive.MaintenanceScheduler` samples written rows into
    a reservoir and, every ``check_every`` writes, checks the plan's escape
    window, refits drifted column models into a new plan version
    (:meth:`install_codec`), and migrates stale escaped blocks — so a
    drifting workload holds its compression factor instead of degrading
    toward raw size.  Pass ``True`` for defaults or a ``MaintenanceConfig``;
    tests can drive ``store.maintenance.step()`` directly.
    """

    name = "blitzcrank"

    def __init__(
        self,
        schema: Sequence[ColumnSpec],
        rows_sample,
        correlation: bool = False,
        block_tuples: int = 1,
        sample: int = 1 << 15,
        use_pallas: bool | None = None,
        auto_merge: bool = True,
        merge_frac: float = 0.06,
        rewrite_frac: float = 0.12,
        merge_min_bytes: int = 1 << 16,
        adaptive: bool | MaintenanceConfig = False,
        codec: Optional[TableCodec] = None,
        memory_budget: Optional[int] = None,
        spill_path: Optional[str] = None,
        spill_io: Optional[Any] = None,
    ):
        super().__init__(schema)
        if codec is None:
            codec = TableCodec.fit(
                rows_sample,
                self.schema,
                correlation=correlation,
                sample=sample,
                block_tuples=block_tuples,
            )
        else:
            # A pre-fitted codec (shared across a repro.db Table's shards:
            # same sample => same models, fit once, count model bytes once)
            block_tuples = codec.block_tuples
        # memory_budget (paper §6.4, DESIGN.md §6) bounds the *compressed
        # arena's* live resident bytes; the bounded delta overlay rides on
        # top and is folded back by merge() as before.
        self.table = CompressedTable(codec, use_pallas=use_pallas,
                                     memory_budget=memory_budget,
                                     spill_path=spill_path,
                                     spill_io=spill_io)
        self.block_tuples = block_tuples
        # Durability hook, same contract as _BytesRowStore.repair_fn.
        self.repair_fn: Optional[Callable] = None
        self.repairs = 0
        self.auto_merge = bool(auto_merge) and block_tuples == 1
        self.merge_frac = merge_frac
        self.rewrite_frac = rewrite_frac
        self.merge_min_bytes = merge_min_bytes
        self._overlay: Dict[int, Dict] = {}
        self._overlay_bytes = 0
        self._tombstones: set = set()
        self.merges = 0
        self.maintenance: MaintenanceScheduler | None = None
        if adaptive and block_tuples == 1:
            cfg = (adaptive if isinstance(adaptive, MaintenanceConfig) else None)
            self.maintenance = MaintenanceScheduler(self, cfg)

    # -- codec versions (DESIGN.md §4) -----------------------------------
    @property
    def codec(self) -> TableCodec:
        """The current (newest) codec; older versions live in the table."""
        return self.table.codec

    @property
    def n_versions(self) -> int:
        return self.table.n_versions

    def install_codec(self, codec: TableCodec) -> int:
        """Install a refit codec as the new plan version (writes use it)."""
        return self.table.install_codec(codec)

    @property
    def plan_epoch(self) -> int:
        """Plan-version counter for the prepared-op cache (DESIGN.md §11):
        bumps on ``install_codec`` (adaptive refit / migrate), stays put
        across merges/rewrites that keep the plan."""
        return self.table.current_version

    def migrate(self, limit: int = 1 << 12, resident_only: bool = True) -> int:
        """Re-encode up to ``limit`` stale escaped rows under the newest
        plan (dirty overlay rows migrate through :meth:`merge` instead).
        Under a memory budget, ``resident_only`` keeps maintenance from
        faulting cold blocks in — background work must not thrash the
        hot set (DESIGN.md §6)."""
        return self.table.migrate_rows(limit, resident_only=resident_only)

    @property
    def n(self) -> int:
        return len(self.table)

    def __len__(self) -> int:
        return len(self.table)

    @property
    def n_live(self) -> int:
        return self.table.n_live - len(self._tombstones)

    def is_live(self, i: int) -> bool:
        i = int(i)
        if i in self._overlay:
            return True
        if i in self._tombstones:
            return False
        return self.table.is_live(i)

    # -- batched protocol ------------------------------------------------
    def insert_many(self, rows: Sequence[Dict[str, Any]]) -> range:
        base = len(self.table)
        self.table.extend(rows)
        if self.maintenance is not None:
            self.maintenance.observe_writes(rows)
            self.maintenance.maybe_step()
        return range(base, len(self.table))

    def get_many(
        self, indices: Sequence[int], backend: str | None = None
    ) -> List[Optional[Dict[str, Any]]]:
        idxs = [int(i) for i in indices]  # materialize: may be an iterator
        for _attempt in range(3):
            try:
                rows = self.table.get_many(idxs, backend=backend)
                break
            except SpillCorruptionError as e:
                self._repair(e)
        else:
            rows = self.table.get_many(idxs, backend=backend)
        if self._overlay or self._tombstones:
            ov, ts = self._overlay, self._tombstones
            rows = [None if i in ts
                    else (dict(ov[i]) if i in ov else r)
                    for i, r in zip(idxs, rows)]
            _C_OVERLAY_HITS.add(sum(1 for i in idxs if i in ov))
        return rows

    def update_many(
        self, indices: Sequence[int], rows: Sequence[Dict[str, Any]]
    ) -> None:
        idxs, rows = self._dedup_last(indices, rows)
        # blitzlint: waive[BL001] -- per-key overlay payload update; the batch was deduped just above
        for i, r in zip(idxs, rows):
            if not self.is_live(i):
                raise KeyError(f"row {i} is deleted")
            old = self._overlay.get(i)
            if old is not None:
                self._overlay_bytes -= _raw_row_bytes(old) + OVERLAY_ENTRY_OVERHEAD
            r = dict(r)
            self._overlay[i] = r
            self._overlay_bytes += _raw_row_bytes(r) + OVERLAY_ENTRY_OVERHEAD
        self._maybe_merge()
        if self.maintenance is not None:
            self.maintenance.observe_writes(rows)
            self.maintenance.maybe_step()

    def scan_where(
        self,
        predicates: Sequence[Any],
        columns: Optional[Sequence[str]] = None,
        pushdown: bool = True,
        backend: str | None = None,
    ) -> "Any":
        """Predicate-pushdown scan over the code arena (DESIGN.md §8).

        The arena scan (``repro.scan.scan_table``) evaluates predicates in
        code space with zone-map pruning and decodes only survivors,
        reading cold blocks through without promoting them.  Arena hits
        shadowed by the delta overlay or store-level tombstones are
        dropped and the overlay is re-filtered in value space, so the
        result matches the reference scan exactly at any merge state.
        ``pushdown=False`` falls back to the decode-everything baseline.
        """
        if not pushdown:
            return super().scan_where(
                predicates, columns=columns, pushdown=False, backend=backend
            )
        from repro.scan import ScanResult, match_all, scan_table
        preds = list(predicates)
        for _attempt in range(3):
            try:
                res = scan_table(self.table, preds, columns=columns, backend=backend)
                break
            except SpillCorruptionError as e:
                self._repair(e)
        else:
            res = scan_table(self.table, preds, columns=columns, backend=backend)
        if not self._overlay and not self._tombstones:
            return res
        ov, ts = self._overlay, self._tombstones
        proj = (columns if columns is not None else list(self.table.codec.order))
        merged: List[Tuple[int, Dict[str, Any]]] = [
            (i, r) for i, r in zip(res.ids, res.rows) if i not in ts and i not in ov
        ]
        for i, r in ov.items():
            if match_all(preds, r):
                merged.append((int(i), {c: r[c] for c in proj}))
        merged.sort(key=lambda h: h[0])
        res.stats.rows_matched = len(merged)
        return ScanResult([h[0] for h in merged], [h[1] for h in merged], res.stats)

    def delete_many(self, indices: Sequence[int]) -> int:
        if self.block_tuples != 1:
            raise ValueError("delete_many requires block_tuples == 1")
        n = 0
        for i in {int(j) for j in indices}:
            if not self.is_live(i):
                continue
            old = self._overlay.pop(i, None)
            if old is not None:
                self._overlay_bytes -= _raw_row_bytes(old) + OVERLAY_ENTRY_OVERHEAD
            self._tombstones.add(i)
            n += 1
        self._maybe_merge()
        return n

    # -- delta-merge compaction (DESIGN.md §3) ---------------------------
    def _maybe_merge(self) -> None:
        if not self.auto_merge:
            return
        delta = (self._overlay_bytes + TOMBSTONE_BYTES * len(self._tombstones))
        if delta > max(self.merge_min_bytes, self.merge_frac * 2 * self.table.used):
            self.merge()

    def merge(self) -> Dict[str, Any]:
        """Fold the delta overlay + tombstones back into the code arena.

        Dirty rows are re-encoded through the bulk ``compress_rows`` path
        (one vectorized ``encode_batch`` for conforming rows) and their old
        runs tombstoned; the arena is rewritten once dead bytes exceed
        ``rewrite_frac`` of the code bytes.  Returns :meth:`stats`.
        """
        if self.block_tuples != 1:
            raise ValueError("merge requires block_tuples == 1")
        t0 = telemetry.clock()
        if sanitize.ENABLED:
            sanitize.check_overlay(
                self._overlay, self._tombstones, where="BlitzStore.merge"
            )
        if self._tombstones:
            self.table.delete_many(sorted(self._tombstones))
            self._tombstones.clear()
        if self._overlay:
            idxs = sorted(self._overlay)
            self.table.replace_many(idxs, [self._overlay[i] for i in idxs])
            self._overlay.clear()
            self._overlay_bytes = 0
        self.merges += 1
        _C_MERGES.inc()
        if self.table.dead_bytes > max(
            self.merge_min_bytes, self.rewrite_frac * 2 * self.table.used
        ):
            self.table.rewrite()
        _H_MERGE.observe_since(t0)
        return self.stats()

    # -- durability (DESIGN.md §7) ---------------------------------------
    def _repair(self, err: SpillCorruptionError) -> None:
        """Rebuild rows whose spilled blocks failed their CRC check.

        ``replace_many`` retires the corrupt blocks *without* reading them
        and re-encodes the WAL-reconstructed rows under the newest plan;
        ids the WAL resolves to "deleted" are tombstoned.  Escape
        accounting is paused — repair traffic is not workload drift."""
        if self.repair_fn is None:
            raise err
        ids = list(err.row_ids)
        fetched = self.repair_fn(ids)
        alive = [(i, r) for i, r in zip(ids, fetched) if r is not None]
        dead = [i for i, r in zip(ids, fetched) if r is None]
        plan = self.table.codec.compile()
        ctx = (plan.pause_escape_accounting() if plan is not None
               else contextlib.nullcontext())
        with ctx:
            if alive:
                self.table.replace_many([i for i, _ in alive], [r for _, r in alive])
            if dead:
                self.table.delete_many(dead)
        self.repairs += len(ids)
        self.table.note_repaired_rows(len(ids))

    def close(self, unlink: bool = False) -> None:
        self.table.close(unlink=unlink)

    def snapshot_state(self) -> Dict[str, Any]:
        for _attempt in range(3):
            try:
                table_state = self.table.snapshot_state()
                break
            except SpillCorruptionError as e:
                self._repair(e)
        else:
            table_state = self.table.snapshot_state()
        return {
            "table": table_state,
            "overlay": {int(i): dict(r)
                        for i, r in self._overlay.items()},
            "overlay_bytes": self._overlay_bytes,
            "tombstones": sorted(self._tombstones),
            "merges": self.merges,
            "flags": {
                "auto_merge": self.auto_merge,
                "merge_frac": self.merge_frac,
                "rewrite_frac": self.rewrite_frac,
                "merge_min_bytes": self.merge_min_bytes,
                "block_tuples": self.block_tuples,
            },
            "maintenance": (self.maintenance.snapshot_state()
                            if self.maintenance is not None else None),
        }

    @classmethod
    def from_state(
        cls,
        schema: Sequence[ColumnSpec],
        state: Dict[str, Any],
        spill_path: Optional[str] = None,
        spill_io: Optional[Any] = None,
    ) -> "BlitzStore":
        self = cls.__new__(cls)
        RowStore.__init__(self, schema)
        self.table = CompressedTable.from_state(
            state["table"], spill_path=spill_path, spill_io=spill_io
        )
        flags = state["flags"]
        self.block_tuples = flags["block_tuples"]
        self.auto_merge = flags["auto_merge"]
        self.merge_frac = flags["merge_frac"]
        self.rewrite_frac = flags["rewrite_frac"]
        self.merge_min_bytes = flags["merge_min_bytes"]
        self._overlay = {int(i): dict(r) for i, r in state["overlay"].items()}
        self._overlay_bytes = state["overlay_bytes"]
        self._tombstones = set(state["tombstones"])
        self.merges = state["merges"]
        self.repair_fn = None
        self.repairs = 0
        self.maintenance = None
        if state.get("maintenance") is not None:
            self.maintenance = MaintenanceScheduler.from_state(
                self, state["maintenance"]
            )
        return self

    # -- accounting ------------------------------------------------------
    @property
    def nbytes(self) -> int:
        """Total footprint: arena (incl. dead bytes) + overlay + tombstones.

        Overlay entries are charged at raw row bytes plus
        ``OVERLAY_ENTRY_OVERHEAD`` (dict key + slot pointer) so compression
        factors are not overstated mid-merge; ``stats()`` reports the
        overlay separately from the arena.
        """
        return (self.table.nbytes + self._overlay_bytes
                + TOMBSTONE_BYTES * len(self._tombstones))

    def model_objects(self) -> List[Any]:
        """Every model object across codec versions (repro.db.Table dedups
        these by identity across shards sharing a fit)."""
        out: List[Any] = []
        for v in range(self.table.n_versions):
            out.extend(self.table.codec_at(v).models.values())
        return out

    @property
    def model_bytes(self) -> int:
        # Codec versions share unchanged model objects; count each once.
        seen: set = set()
        total = 0
        for m in self.model_objects():
            if id(m) not in seen:
                seen.add(id(m))
                total += m.model_bytes()
        return total

    def stats(self) -> Dict[str, Any]:
        t = self.table
        plans = [t.codec_at(v).compile() for v in range(t.n_versions)]
        plan = plans[-1]
        # Cumulative escapes aggregate over every plan version's lifetime;
        # the window counters (drift signal, DESIGN.md §4) are the current
        # plan's open window only.
        escapes: Dict[str, int] = {}
        for p in plans:
            if p is not None:
                for k, v in p.escape_counts.items():
                    escapes[k] = escapes.get(k, 0) + v
        n_blocks = t.n_blocks
        out = {
            "name": self.name,
            "n_ids": len(t),
            "n_live": self.n_live,
            "n_deleted": len(t) - self.n_live,
            "nbytes": self.nbytes,
            "arena_bytes": t.nbytes,
            "dead_bytes": t.dead_bytes,
            "overlay_bytes": self._overlay_bytes,
            "overlay_rows": len(self._overlay),
            "tombstones": len(self._tombstones),
            "merges": self.merges,
            "rewrites": t.rewrites,
            "model_bytes": self.model_bytes,
            "fast_fraction": (float(t.block_fast.mean())
                              if n_blocks else 0.0),
            # §5 dynamic value sets: cumulative per-column model misses ...
            "escapes": escapes,
            # ... and the current drift window (resets on refit/dismissal).
            "escapes_window": (dict(plan.window_escapes)
                               if plan is not None else {}),
            "window_rows": plan.window_rows if plan is not None else 0,
            "plan_versions": t.n_versions,
            "version_rows": t.version_rows(),
            "migrated_rows": t.migrated_rows,
            "plan_fallback": (None if plan is not None
                              else self.codec.plan_fallback_reason),
        }
        out["telemetry"] = telemetry.snapshot(prefix=self.TELEMETRY_PREFIXES)
        if self.repairs:
            out["repairs"] = self.repairs
        if t.memory_budget is not None:
            # nbytes above is *resident* memory (how the paper counts the
            # budget); the on-disk cold tier is reported separately.
            out["spilled_bytes"] = t.spilled_bytes
            out["residency"] = t.residency()
        if self.maintenance is not None:
            out["maintenance"] = self.maintenance.stats()
        return out


class ZstdStore(_BytesRowStore):
    name = "zstd"

    def __init__(
        self,
        schema: Sequence[ColumnSpec],
        rows_sample,
        dict_kb: int = 110,
        level: int = 3,
        memory_budget: Optional[int] = None,
        spill_path: Optional[str] = None,
        spill_io: Optional[Any] = None,
    ):
        super().__init__(
            schema,
            memory_budget=memory_budget,
            spill_path=spill_path,
            spill_io=spill_io,
        )
        import zstandard as zstd
        self.level = level
        samples = [
            json.dumps([r[c.name] for c in self.schema]).encode() for r in rows_sample
        ]
        try:
            dict_data = zstd.train_dictionary(dict_kb * 1024, samples)
            self._set_dict(dict_data.as_bytes())
        except Exception:  # tiny sample sets cannot train a dictionary
            self._set_dict(None)

    def _set_dict(self, dict_bytes: Optional[bytes]) -> None:
        import zstandard as zstd
        if dict_bytes is not None:
            dict_data = zstd.ZstdCompressionDict(dict_bytes)
            self._dict = dict_data
            self.cctx = zstd.ZstdCompressor(level=self.level, dict_data=dict_data)
            self.dctx = zstd.ZstdDecompressor(dict_data=dict_data)
            self.dict_bytes = len(dict_bytes)
        else:
            self._dict = None
            self.cctx = zstd.ZstdCompressor(level=self.level)
            self.dctx = zstd.ZstdDecompressor()
            self.dict_bytes = 0

    def _snapshot_model(self) -> Any:
        return {
            "level": self.level,
            "dict": (self._dict.as_bytes() if self._dict is not None else None),
        }

    def _restore_model(self, state: Any) -> None:
        self.level = state["level"]
        self._set_dict(state["dict"])

    def _encode_row(self, row: Dict[str, Any]) -> bytes:
        raw = json.dumps([row[c.name] for c in self.schema]).encode()
        return self.cctx.compress(raw)

    def _decode_row(self, raw: bytes) -> Dict[str, Any]:
        vals = json.loads(self.dctx.decompress(raw))
        return {c.name: v for c, v in zip(self.schema, vals)}

    def insert_many(self, rows: Sequence[Dict[str, Any]]) -> range:
        """Bulk insert through ``multi_compress_to_buffer`` when available:
        one C call over all payloads, amortizing context setup."""
        schema = self.schema
        payloads = [json.dumps([r[c.name] for c in schema]).encode() for r in rows]
        frames = None
        if len(payloads) > 1 and hasattr(self.cctx, "multi_compress_to_buffer"):
            try:
                segs = self.cctx.multi_compress_to_buffer(payloads)
                frames = [segs[i].tobytes() for i in range(len(segs))]
            except Exception:
                frames = None
        if frames is None:
            comp = self.cctx.compress
            frames = [comp(p) for p in payloads]
        return self._append_payloads(frames)

    def get_many(
        self, indices: Sequence[int], backend: Optional[str] = None
    ) -> List[Optional[Dict[str, Any]]]:
        """Batched point gets: one ``multi_decompress_to_buffer`` C call for
        the whole batch when the library supports it."""
        idxs = [int(i) for i in indices]
        dels = self._deleted
        live = [j for j, i in enumerate(idxs) if i not in dels]
        out: List[Optional[Dict[str, Any]]] = [None] * len(idxs)
        if self._res is None:
            frames = [self.rows[idxs[j]] for j in live]
        else:
            fetched = self._fetch_payloads(idxs)
            frames = [fetched[j] for j in live]
        raws = None
        if len(frames) > 1 and hasattr(self.dctx, "multi_decompress_to_buffer"):
            try:
                segs = self.dctx.multi_decompress_to_buffer(frames)
                raws = [segs[i].tobytes() for i in range(len(segs))]
            except Exception:
                raws = None
        if raws is None:
            dec = self.dctx.decompress
            raws = [dec(f) for f in frames]
        schema = self.schema
        for j, raw in zip(live, raws):
            vals = json.loads(raw)
            out[j] = {c.name: v for c, v in zip(schema, vals)}
        return out

    @property
    def model_bytes(self) -> int:
        return self.dict_bytes


class RamanStore(_BytesRowStore):
    """Per-column Huffman over value ids (static dictionary baseline §6).

    Values unseen at train time go through a length-prefixed byte escape.
    Numeric columns are coded on their value dictionary too (Raman & Swart
    treat fields as symbols); tuples are concatenated variable-length codes.
    """

    name = "raman"

    def __init__(
        self,
        schema: Sequence[ColumnSpec],
        rows_sample,
        memory_budget: Optional[int] = None,
        spill_path: Optional[str] = None,
        spill_io: Optional[Any] = None,
    ):
        super().__init__(
            schema,
            memory_budget=memory_budget,
            spill_path=spill_path,
            spill_io=spill_io,
        )
        self.columns = {}
        for c in self.schema:
            vals = [r[c.name] for r in rows_sample]
            uniq: Dict[Any, int] = {}
            counts: List[float] = []
            # blitzlint: waive[BL001] -- Raman fit-time frequency estimation over the sample, not the op path
            for v in vals:
                j = uniq.setdefault(v, len(uniq))
                if j == len(counts):
                    counts.append(0.0)
                counts[j] += 1
            # reserve an escape symbol
            uniq["\x00<esc>"] = len(uniq)
            counts.append(max(1.0, 0.01 * len(vals)))
            self.columns[c.name] = (
                uniq, list(uniq.keys()), HuffmanCode(np.asarray(counts))
            )
        # hoisted per-column (name, value->id, esc_id, id->value, code)
        self._cols = [(c.name, *self.columns[c.name],
                       self.columns[c.name][0]["\x00<esc>"])
                      for c in self.schema]

    def _snapshot_model(self) -> Any:
        return {"columns": self.columns}

    def _restore_model(self, state: Any) -> None:
        self.columns = state["columns"]
        self._cols = [(c.name, *self.columns[c.name],
                       self.columns[c.name][0]["\x00<esc>"])
                      for c in self.schema]

    def _encode_row(self, row: Dict[str, Any]) -> bytes:
        bw = BitWriter()
        for name, uniq, _, hc, esc in self._cols:
            v = row[name]
            j = uniq.get(v)
            if j is None:
                hc.encode(esc, bw)
                payload = json.dumps(v).encode()
                bw.write(len(payload), 16)
                for byte in payload:
                    bw.write(byte, 8)
            else:
                hc.encode(j, bw)
        return bw.getvalue()[0]

    def _decode_row(self, raw: bytes) -> Dict[str, Any]:
        br = BitReader(raw)
        out = {}
        for name, _, keys, hc, esc in self._cols:
            j = hc.decode(br)
            if j == esc:
                ln = br.peek(16)
                br.skip(16)
                data = bytearray()
                for _ in range(ln):
                    data.append(br.peek(8))
                    br.skip(8)
                out[name] = json.loads(bytes(data))
            else:
                out[name] = keys[j]
        return out

    @property
    def model_bytes(self) -> int:
        total = 0
        for name, (uniq, keys, hc) in self.columns.items():
            total += sum(len(str(k)) + 10 for k in keys)
        return total


class LRUFastPath(RowStore):
    """§6.5 write-back cache of decompressed tuples above any RowStore.

    Speaks the full protocol: reads are served from the cache when hot and
    batch-fetched through the store's ``get_many`` otherwise; updates are
    buffered dirty in the cache and written back to the underlying store
    (``update_many``) on eviction and on :meth:`sync`, so
    ``read_modify_write`` never loses data once the cache fills.
    """

    name = "lru"

    def __init__(self, store: "UncompressedStore", capacity: int) -> None:
        super().__init__(getattr(store, "schema", None))
        self.store = store
        self.capacity = capacity
        self.cache: OrderedDict[int, Dict] = OrderedDict()
        self.dirty: set = set()
        self.hits = 0
        self.misses = 0
        self.writebacks = 0

    def _writeback(self, i: int, row: Dict[str, Any]) -> None:
        self.dirty.discard(i)
        self.writebacks += 1
        self.store.update(i, row)

    def _evict(self) -> None:
        while len(self.cache) > self.capacity:
            i, row = self.cache.popitem(last=False)
            if i in self.dirty:
                self._writeback(i, row)

    def read_modify_write(self, i: int, update_fn) -> None:
        row = self.cache.get(i)
        if row is not None:
            self.hits += 1
            self.cache.move_to_end(i)
        else:
            self.misses += 1
            row = self.store.get(i)
            self.cache[i] = row
        # Apply the update and mark dirty BEFORE evicting: with a full (or
        # zero-capacity) cache the evicted row may be this one, and the
        # write-back must carry the new value.
        update_fn(row)
        self.dirty.add(i)
        self._evict()

    def get(self, i: int) -> Dict[str, Any]:
        row = self.cache.get(i)
        if row is not None:
            self.hits += 1
            self.cache.move_to_end(i)
            return dict(row)  # a copy: callers must not alias the cache
        self.misses += 1
        return self.store.get(i)

    def sync(self) -> None:
        """Flush all dirty cached rows back in one ``update_many`` call."""
        idxs = [i for i in self.dirty if i in self.cache]
        if idxs:
            self.store.update_many(idxs, [self.cache[i] for i in idxs])
            self.writebacks += len(idxs)
        self.dirty.clear()

    # -- batched protocol ------------------------------------------------
    def insert_many(self, rows: Sequence[Dict[str, Any]]) -> range:
        return self.store.insert_many(rows)

    def get_many(
        self, indices: Sequence[int], backend: Optional[str] = None
    ) -> List[Optional[Dict[str, Any]]]:
        idxs = [int(i) for i in indices]
        out: List[Optional[Dict[str, Any]]] = [None] * len(idxs)
        miss_pos: List[int] = []
        cache = self.cache
        for j, i in enumerate(idxs):
            row = cache.get(i)
            if row is not None:
                self.hits += 1
                cache.move_to_end(i)
                out[j] = dict(row)  # copies: callers must not alias the cache
            else:
                miss_pos.append(j)
        if miss_pos:
            self.misses += len(miss_pos)
            fetched = self.store.get_many(
                [idxs[j] for j in miss_pos], backend=backend
            )
            for j, row in zip(miss_pos, fetched):
                if row is None:
                    continue  # tombstone: never cached
                i = idxs[j]
                if i in cache:  # duplicate miss within this batch
                    row = cache[i]
                else:
                    cache[i] = row
                out[j] = dict(row)
            self._evict()
        return out

    def update_many(
        self, indices: Sequence[int], rows: Sequence[Dict[str, Any]]
    ) -> None:
        idxs, rows = self._dedup_last(indices, rows)
        # blitzlint: waive[BL001] -- baseline row-cache update maintains per-key recency (not the hot store)
        for i, r in zip(idxs, rows):
            if not self.is_live(i):
                raise KeyError(f"row {i} is deleted")
            self.cache[i] = dict(r)
            self.cache.move_to_end(i)
            self.dirty.add(i)
        self._evict()

    def delete_many(self, indices: Sequence[int]) -> int:
        idxs = {int(i) for i in indices}
        for i in idxs:
            self.cache.pop(i, None)
            self.dirty.discard(i)
        return self.store.delete_many(idxs)

    def scan(
        self, start: int = 0, stop: Optional[int] = None, batch: int = 1024
    ) -> Iterator[Tuple[int, Dict[str, Any]]]:
        self.sync()  # the underlying store must see dirty rows
        return self.store.scan(start, stop, batch)

    def scan_where(
        self,
        predicates: Sequence[Any],
        columns: Optional[Sequence[str]] = None,
        pushdown: bool = True,
        backend: Optional[str] = None,
    ) -> "Any":
        self.sync()  # the underlying store must see dirty rows
        return self.store.scan_where(
            predicates, columns=columns, pushdown=pushdown, backend=backend
        )

    def is_live(self, i: int) -> bool:
        return int(i) in self.cache or self.store.is_live(i)

    def __len__(self) -> int:
        return len(self.store)

    @property
    def n_live(self) -> int:
        return self.store.n_live

    @property
    def nbytes(self) -> int:
        return self.store.nbytes + sum(
            _raw_row_bytes(r) + OVERLAY_ENTRY_OVERHEAD for r in self.cache.values()
        )

    def stats(self) -> Dict[str, Any]:
        s = dict(self.store.stats())
        s.update(nbytes=self.nbytes,  # include the cached rows (§3.4)
                 cache_rows=len(self.cache), cache_hits=self.hits,
                 cache_misses=self.misses, writebacks=self.writebacks)
        return s


STORE_KINDS = MappingProxyType({
    "silo": UncompressedStore,
    "blitzcrank": BlitzStore,
    "zstd": ZstdStore,
    "raman": RamanStore,
})
