"""Central catalog of every telemetry series name (DESIGN.md §9/§10).

One flat tuple, one name per series.  blitzlint rule BL002 parses this
file (without importing it) and fails CI when a literal name at a call
site is missing here — so a typo can no longer fork a metric series —
and when the catalog itself holds a duplicate or a name that violates
the ``repro.<subsystem>.<verb>`` pattern.

Names constructed dynamically (the ``repro.scan.<field>`` counters
generated from ``ScanStats._FIELDS``) are enumerated here explicitly and
pinned by ``tests/test_blitzlint.py::test_scan_stats_fields_catalogued``.
"""

from __future__ import annotations

from typing import FrozenSet, Tuple

METRICS: Tuple[str, ...] = (
    # -- core encode/decode (leaf phases of the wall-time breakdown) ----
    "repro.core.decode",
    "repro.core.decode.rows",
    "repro.core.decode.scalar_block",
    "repro.core.encode",
    "repro.core.encode.rows",
    "repro.core.encode.scalar",
    "repro.core.encode.scalar_block",
    # -- plan compilation and kernel caches -----------------------------
    "repro.plan.cache.hit",
    "repro.plan.cache.miss",
    "repro.plan.cache.pallas_hit",
    "repro.plan.cache.pallas_miss",
    "repro.plan.compile",
    "repro.plan.compile.pallas_jit",
    "repro.plan.pallas_pack",
    "repro.plan.pallas_pack.events",
    # -- residency / out-of-core tier ------------------------------------
    "repro.residency.fault_in",
    "repro.residency.fault_in.blocks",
    "repro.residency.fault_in.rows",
    "repro.residency.fault_in.rows.count",
    "repro.residency.spill",
    "repro.residency.spill.blocks",
    "repro.residency.spill.rows",
    "repro.residency.spill.rows.count",
    # -- row stores -------------------------------------------------------
    "repro.store.merge",
    "repro.store.merge.events",
    "repro.store.migrate.rows",
    "repro.store.overlay.hits",
    "repro.store.rewrite",
    # -- write-ahead log --------------------------------------------------
    "repro.wal.append",
    "repro.wal.bytes",
    "repro.wal.fsync",
    "repro.wal.fsyncs",
    "repro.wal.records",
    # -- compiled execution engine (plan/run split, DESIGN.md §11) ---------
    "repro.exec.lower",
    "repro.exec.plan.hit",
    "repro.exec.plan.miss",
    "repro.exec.replay",
    "repro.exec.replay.rows",
    # -- db engine (batched verbs; span + rows-counter pairs) -------------
    "repro.db.delete_many",
    "repro.db.delete_many.rows",
    "repro.db.get_many",
    "repro.db.get_many.rows",
    "repro.db.insert_many",
    "repro.db.insert_many.rows",
    "repro.db.shard_calls",
    "repro.db.update_many",
    "repro.db.update_many.rows",
    # -- scan engine (repro.scan.<field> mirrors ScanStats._FIELDS) -------
    "repro.scan.blocks_fallback",
    "repro.scan.blocks_lut",
    "repro.scan.blocks_pruned",
    "repro.scan.blocks_scalar",
    "repro.scan.blocks_total",
    "repro.scan.rows_decoded",
    "repro.scan.rows_matched",
    "repro.scan.rows_prefix_decoded",
    "repro.scan.scan_table",
    "repro.scan.spilled_reads",
    "repro.scan.versions",
    # -- sanitizer (DESIGN.md §10: boundary-check accounting) --------------
    "repro.sanitize.checks",
    "repro.sanitize.failures",
    # -- benchmark self-instrumentation ------------------------------------
    "repro.bench.telemetry.counter",
    "repro.bench.telemetry.hist",
)

CATALOG: FrozenSet[str] = frozenset(METRICS)


def is_catalogued(name: str) -> bool:
    """True when ``name`` is a registered series name."""
    return name in CATALOG
