"""Unified telemetry layer (DESIGN.md §9): one engine-wide metrics
registry, nestable trace spans over a ring-buffer event log, and
snapshot exporters.

Quickstart::

    from repro import telemetry

    H = telemetry.histogram("repro.db.get_many")   # cached handle
    t0 = telemetry.clock()                         # 0 when disabled
    ...hot path...
    H.observe_since(t0)                            # no-op on 0

    telemetry.snapshot()        # counters + histogram percentiles
    telemetry.to_prometheus()   # scrape-format text
    telemetry.set_enabled(False)  # near-zero-cost off switch

Metric names follow ``repro.<subsystem>.<verb>``; durations are stored
in nanoseconds and exported in microseconds.
"""

from .export import (
    PHASE_SOURCES,
    dumps,
    phase_breakdown,
    snapshot,
    to_prometheus,
)
from .metrics import (
    BUCKETS_PER_OCTAVE,
    N_BUCKETS,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    Registry,
    bucket_index,
    bucket_lo,
    clock,
    enabled,
    set_enabled,
)
from .spans import (
    EVENTS,
    EventLog,
    Span,
    SpanEvent,
    events_snapshot,
    record,
    span,
)


def counter(name: str) -> Counter:
    """Get-or-create a counter in the engine-wide registry."""
    return REGISTRY.counter(name)


def gauge(name: str) -> Gauge:
    return REGISTRY.gauge(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def reset() -> None:
    """Zero the engine-wide registry and event ring (handles stay valid)."""
    REGISTRY.reset()
    EVENTS.reset()
