"""Low-overhead metrics primitives: counters, gauges, log-bucketed
latency histograms, and the registry that names them (DESIGN.md §9).

Design constraints, in priority order:

1. **Near-zero cost when disabled.**  Every mutating entry point checks
   one module-level flag and returns; ``clock()`` returns 0 so the
   paired ``observe_since(0)`` is a no-op too.  Instrumented code never
   branches on telemetry state itself — it always calls the same
   handles, which are cheap either way.
2. **Cheap when enabled.**  A counter bump is one attribute add; a
   histogram observation is one ``perf_counter_ns`` delta, one
   ``bit_length``-style log2, and two integer adds.  Handles are
   created once at import/module scope and cached by name, so the hot
   path never touches the registry dict.
3. **Lossless merge.**  Histograms store integer bucket counts plus
   exact count/sum/min/max, so ``merge`` is commutative, associative,
   and equal to having observed the concatenated samples into one
   histogram — shard-local histograms fold into a whole-engine view
   without approximation beyond the shared bucket geometry.

Naming convention: ``repro.<subsystem>.<verb>`` (see DESIGN.md §9.2).
Durations are recorded in nanoseconds and exported in microseconds.
"""

from __future__ import annotations

import math
import threading
import time
from typing import Dict, List, Optional

# Histogram geometry: 4 buckets per octave (bucket i spans
# [2**(i/4), 2**((i+1)/4)) nanoseconds), 256 buckets total — 1 ns up to
# ~2 hours, with <=19% relative bucket width everywhere.
BUCKETS_PER_OCTAVE = 4
N_BUCKETS = 256
_LOG2_E4 = BUCKETS_PER_OCTAVE / math.log(2.0)


class _State:
    __slots__ = ("enabled",)

    def __init__(self) -> None:
        self.enabled = True


_state = _State()


def enabled() -> bool:
    """Is telemetry recording anything right now?"""
    return _state.enabled


def set_enabled(on: bool) -> bool:
    """Flip recording on/off globally; returns the previous state.

    Disabling must never change engine behaviour — only whether the
    registry accumulates.  (tests/test_telemetry.py proves enabled and
    disabled runs produce bit-identical store contents.)
    """
    prev = _state.enabled
    _state.enabled = bool(on)
    return prev


def clock() -> int:
    """Start-of-region timestamp: ``perf_counter_ns`` when enabled, else 0.

    Pair with :meth:`Histogram.observe_since` — a 0 start makes the
    observe a no-op, so a disabled region costs one flag check total.
    """
    return time.perf_counter_ns() if _state.enabled else 0


def bucket_index(ns: float) -> int:
    """Bucket holding a duration of ``ns`` nanoseconds (clamped)."""
    if ns < 1.0:
        return 0
    i = int(math.log(ns) * _LOG2_E4)
    return i if i < N_BUCKETS else N_BUCKETS - 1


def bucket_lo(i: int) -> float:
    """Inclusive lower edge of bucket ``i`` in nanoseconds."""
    return 2.0 ** (i / BUCKETS_PER_OCTAVE)


class Counter:
    """Monotonic event counter.  ``add`` is the only hot entry point."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0

    def add(self, n: int = 1) -> None:
        if _state.enabled:
            self.value += n

    def inc(self) -> None:
        self.add(1)

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-write-wins instantaneous value (bytes resident, queue depth)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str) -> None:
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        if _state.enabled:
            self.value = float(v)

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Log-bucketed latency histogram over nanosecond durations.

    Buckets are global geometry (module constants), so any two
    histograms merge losslessly by adding bucket counts; count/sum are
    exact and min/max are exact extremes, making ``merge`` commutative
    and associative.
    """

    __slots__ = ("name", "count", "sum_ns", "min_ns", "max_ns", "buckets")

    def __init__(self, name: str) -> None:
        self.name = name
        self.count = 0
        self.sum_ns = 0
        self.min_ns = 0.0
        self.max_ns = 0.0
        self.buckets: List[int] = [0] * N_BUCKETS

    def observe(self, ns: float) -> None:
        """Record one duration (nanoseconds)."""
        if not _state.enabled:
            return
        if ns < 0:
            ns = 0
        if self.count == 0 or ns < self.min_ns:
            self.min_ns = ns
        if ns > self.max_ns:
            self.max_ns = ns
        self.count += 1
        self.sum_ns += int(ns)
        self.buckets[bucket_index(ns)] += 1

    def observe_since(self, t0_ns: int) -> None:
        """Record the elapsed time since a :func:`clock` start (no-op on 0)."""
        if t0_ns:
            self.observe(time.perf_counter_ns() - t0_ns)

    def percentile(self, q: float) -> float:
        """q-quantile in nanoseconds (geometric bucket midpoint); 0.0 when
        empty — an unobserved histogram has no latency to report."""
        if self.count == 0:
            return 0.0
        want = q * self.count
        seen = 0
        for i, c in enumerate(self.buckets):
            seen += c
            if seen >= want and c:
                mid = 2.0 ** ((i + 0.5) / BUCKETS_PER_OCTAVE)
                # clamp to the exact extremes so tiny histograms don't
                # report a midpoint outside the observed range
                return min(max(mid, self.min_ns), self.max_ns)
        return self.max_ns  # pragma: no cover - count>0 guarantees a hit

    def merge(self, other: "Histogram") -> None:
        """Fold ``other`` in; lossless (see class docstring)."""
        if other.count == 0:
            return
        if self.count == 0 or other.min_ns < self.min_ns:
            self.min_ns = other.min_ns
        if other.max_ns > self.max_ns:
            self.max_ns = other.max_ns
        self.count += other.count
        self.sum_ns += other.sum_ns
        b, ob = self.buckets, other.buckets
        for i in range(N_BUCKETS):
            b[i] += ob[i]

    def total_seconds(self) -> float:
        return self.sum_ns / 1e9

    def summary(self) -> Dict[str, float]:
        """Exporter view: count plus total/percentiles in microseconds."""
        return {
            "count": self.count,
            "total_s": round(self.sum_ns / 1e9, 6),
            "p50_us": round(self.percentile(0.50) / 1e3, 3),
            "p95_us": round(self.percentile(0.95) / 1e3, 3),
            "p99_us": round(self.percentile(0.99) / 1e3, 3),
            "max_us": round(self.max_ns / 1e3, 3),
        }

    def reset(self) -> None:
        self.count = 0
        self.sum_ns = 0
        self.min_ns = 0.0
        self.max_ns = 0.0
        self.buckets = [0] * N_BUCKETS


class Registry:
    """Name -> metric map.  Handles are created once and cached by the
    instrumented modules, so lookups are off the hot path; creation is
    locked so concurrent first-touch is safe."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, Histogram(name))
        return h

    def counters(self) -> Dict[str, Counter]:
        return dict(self._counters)

    def gauges(self) -> Dict[str, Gauge]:
        return dict(self._gauges)

    def histograms(self) -> Dict[str, Histogram]:
        return dict(self._hists)

    def hist_seconds(self, prefix: Optional[str] = None) -> Dict[str, float]:
        """name -> accumulated seconds for every histogram (phase math)."""
        return {
            n: h.total_seconds()
            for n, h in self._hists.items()
            if prefix is None or n.startswith(prefix)
        }

    def reset(self) -> None:
        """Zero every metric *in place* — cached handles stay valid."""
        for c in self._counters.values():
            c.reset()
        for g in self._gauges.values():
            g.reset()
        for h in self._hists.values():
            h.reset()


# The engine-wide default registry.  Per-object registries are possible
# (tests use them) but the engine instruments against this one: metric
# names are globally meaningful, like a process's /metrics page.
REGISTRY = Registry()
