"""Snapshot exporters: structured JSON and Prometheus-style text, plus
the per-phase wall-time breakdown the benchmarks emit (DESIGN.md §9.3).

The phase map answers "where does a transaction's wall time go" by
folding every timing histogram into six named phases.  Phases are
*leaf* regions (the instrumented code times the innermost kernel call,
not the enclosing verb), so their sums are disjoint and the residual —
``python_glue`` — is exactly the interpreter time between kernels: the
number the 7.5x OLTP gap hunt is about.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

from .metrics import REGISTRY, Registry, _state
from .spans import EVENTS, EventLog, events_snapshot

# phase -> histogram-name prefixes whose total time it absorbs.  Every
# prefix is a leaf region; see the module docstring for why that makes
# the sums disjoint.
PHASE_SOURCES: Dict[str, Tuple[str, ...]] = {
    "encode": ("repro.core.encode",),
    "decode": ("repro.core.decode",),
    "jit_compile": ("repro.plan.compile", "repro.plan.pallas_pack",
                    "repro.exec.lower"),
    "fsync": ("repro.wal.fsync",),
    "fault_in": ("repro.residency.fault_in",),
    "spill": ("repro.residency.spill",),
}


def snapshot(
    registry: Optional[Registry] = None,
    prefix: Optional[Tuple[str, ...] | str] = None,
    events: bool = False,
    log: Optional[EventLog] = None,
) -> Dict:
    """JSON-friendly view of the registry: counters, gauges, histogram
    summaries (count + total + p50/p95/p99/max in microseconds).

    ``prefix`` filters metric names — the per-subsystem ``stats()``
    sections use it so a store reports store/core/wal metrics, not the
    whole engine.  ``events=True`` appends the tail of the span ring.
    """
    reg = registry or REGISTRY

    def keep(name: str) -> bool:
        return prefix is None or name.startswith(prefix)

    out: Dict = {
        "enabled": _state.enabled,
        "counters": {
            n: c.value for n, c in sorted(reg.counters().items()) if keep(n)
        },
        "gauges": {n: g.value for n, g in sorted(reg.gauges().items()) if keep(n)},
        "histograms": {
            n: h.summary()
            for n, h in sorted(reg.histograms().items())
            if keep(n) and h.count
        },
    }
    if events:
        out["events"] = events_snapshot(log or EVENTS)
    return out


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def to_prometheus(registry: Optional[Registry] = None) -> str:
    """Prometheus-style text exposition of the registry.

    Counters export as ``<name>_total``; histograms as a summary
    (quantile-labelled gauges plus ``_sum``/``_count``) — enough for a
    scrape-and-graph loop without pulling in a client library.
    """
    reg = registry or REGISTRY
    lines: List[str] = []
    for n, c in sorted(reg.counters().items()):
        pn = _prom_name(n)
        lines.append(f"# TYPE {pn}_total counter")
        lines.append(f"{pn}_total {c.value}")
    for n, g in sorted(reg.gauges().items()):
        pn = _prom_name(n)
        lines.append(f"# TYPE {pn} gauge")
        lines.append(f"{pn} {g.value}")
    for n, h in sorted(reg.histograms().items()):
        if not h.count:
            continue
        pn = _prom_name(n) + "_us"
        lines.append(f"# TYPE {pn} summary")
        for q in (0.5, 0.95, 0.99):
            lines.append(f'{pn}{{quantile="{q}"}} {h.percentile(q) / 1e3:.3f}')
        lines.append(f"{pn}_sum {h.sum_ns / 1e3:.3f}")
        lines.append(f"{pn}_count {h.count}")
    return "\n".join(lines) + "\n"


def phase_breakdown(
    wall_s: float,
    registry: Optional[Registry] = None,
    since: Optional[Dict[str, float]] = None,
) -> Dict:
    """Fold the timing histograms into the six-phase wall-time breakdown.

    ``since`` is a prior ``Registry.hist_seconds()`` map; passing it
    diffs against that point so a bench can scope the breakdown to just
    its measured region without resetting the registry.  ``coverage`` is
    the measured (non-residual) fraction of wall time; the residual is
    reported as the ``python_glue`` phase.
    """
    reg = registry or REGISTRY
    sums = reg.hist_seconds()
    if since:
        sums = {n: v - since.get(n, 0.0) for n, v in sums.items()}
    phases: Dict[str, float] = {}
    for phase, prefixes in PHASE_SOURCES.items():
        phases[phase] = round(
            sum(v for n, v in sums.items() if n.startswith(prefixes)), 6
        )
    measured = sum(phases.values())
    wall_s = float(wall_s)
    glue = max(0.0, wall_s - measured)
    phases["python_glue"] = round(glue, 6)
    total = measured + glue
    return {
        "wall_s": round(wall_s, 6),
        "phases_s": phases,
        "phase_frac": {
            n: round(v / wall_s, 4) if wall_s > 0 else 0.0
            for n, v in phases.items()
        },
        # fraction of wall the phases sum to.  ~1.0 is healthy; far above
        # 1.0 means timers double-count (a leaf landed inside another
        # leaf); far below can't happen by construction (the residual is
        # python_glue) — so the CI gate checks coverage >= 0.9 AND the
        # kernel phases being separately present.
        "coverage": round(total / wall_s, 4) if wall_s > 0 else 0.0,
        # the directly-instrumented share of wall; 1 - measured_frac is
        # interpreter glue — the 7.5x-gap number (DESIGN.md §9.4)
        "measured_frac": round(measured / wall_s, 4) if wall_s > 0 else 0.0,
    }


def dumps(registry: Optional[Registry] = None, **kw) -> str:
    return json.dumps(snapshot(registry, **kw), indent=2, sort_keys=True)
