"""Nestable trace spans over a fixed-size ring-buffer event log.

A span times one logical region (``with span("repro.db.get_many"):``),
feeds its duration into the same-named registry histogram, and appends
a compact event tuple to a bounded ring buffer — the last N operations
are always inspectable without unbounded memory growth.  Nesting is
tracked per thread; the recorded ``depth`` reconstructs the call tree.

Disabled mode returns one shared no-op span object: no allocation, no
clock reads, no ring writes.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, NamedTuple, Optional

from .metrics import REGISTRY, Registry, _state

DEFAULT_EVENT_CAPACITY = 1024


class SpanEvent(NamedTuple):
    seq: int  # monotonically increasing across wraparound
    name: str
    depth: int  # nesting level at the time the span ran
    start_ns: int  # perf_counter_ns at entry
    dur_ns: int


class EventLog:
    """Fixed-capacity ring buffer of :class:`SpanEvent` s.

    ``append`` overwrites the oldest entry once full; ``total`` keeps
    counting, so ``total - len(self)`` is the number of dropped events.
    """

    def __init__(self, capacity: int = DEFAULT_EVENT_CAPACITY) -> None:
        if capacity <= 0:
            raise ValueError("EventLog capacity must be positive")
        self.capacity = capacity
        self._buf: List[Optional[SpanEvent]] = [None] * capacity
        self._next = 0
        self.total = 0

    def append(self, ev: SpanEvent) -> None:
        self._buf[self._next] = ev
        self._next = (self._next + 1) % self.capacity
        self.total += 1

    def __len__(self) -> int:
        return min(self.total, self.capacity)

    def events(self) -> List[SpanEvent]:
        """Retained events, oldest first (wraparound unrolled)."""
        if self.total <= self.capacity:
            return [e for e in self._buf[: self.total] if e is not None]
        return [
            e
            for e in self._buf[self._next :] + self._buf[: self._next]
            if e is not None
        ]

    def reset(self) -> None:
        self._buf = [None] * self.capacity
        self._next = 0
        self.total = 0


EVENTS = EventLog()

_tls = threading.local()


class Span:
    """One active timed region; re-entrant use creates nested events."""

    __slots__ = ("name", "registry", "log", "_t0", "_depth")

    def __init__(self, name: str, registry: Registry, log: EventLog) -> None:
        self.name = name
        self.registry = registry
        self.log = log
        self._t0 = 0
        self._depth = 0

    def __enter__(self) -> "Span":
        self._depth = getattr(_tls, "depth", 0)
        _tls.depth = self._depth + 1
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc: Any) -> None:
        dur = time.perf_counter_ns() - self._t0
        _tls.depth = self._depth
        self.registry.histogram(self.name).observe(dur)
        self.log.append(
            SpanEvent(self.log.total, self.name, self._depth, self._t0, dur)
        )


class _NullSpan:
    """Shared do-nothing span handed out while telemetry is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass


_NULL = _NullSpan()


def span(
    name: str,
    registry: Optional[Registry] = None,
    log: Optional[EventLog] = None,
) -> Any:
    """Context manager timing one region into histogram ``name`` and the
    event ring.  Returns a shared no-op when telemetry is disabled."""
    if not _state.enabled:
        return _NULL
    return Span(name, registry or REGISTRY, log or EVENTS)


def record(
    name: str,
    t0_ns: int,
    registry: Optional[Registry] = None,
    log: Optional[EventLog] = None,
) -> None:
    """Manual span close for code that can't use ``with`` (multiple
    returns, no reindent): pair with a :func:`repro.telemetry.clock`
    start.  No-op when the start was taken disabled (``t0_ns == 0``)."""
    if not t0_ns:
        return
    dur = time.perf_counter_ns() - t0_ns
    (registry or REGISTRY).histogram(name).observe(dur)
    elog = log or EVENTS
    elog.append(SpanEvent(elog.total, name, getattr(_tls, "depth", 0), t0_ns, dur))


def events_snapshot(log: Optional[EventLog] = None, limit: int = 64) -> List[Dict]:
    """Last ``limit`` retained events as JSON-friendly dicts (newest last)."""
    evs = (log or EVENTS).events()[-limit:]
    return [
        {
            "seq": e.seq,
            "name": e.name,
            "depth": e.depth,
            "dur_us": round(e.dur_ns / 1e3, 3),
        }
        for e in evs
    ]
