import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh).

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 host-platform placeholder devices.

For every runnable cell this driver:
  1. builds the jitted step (train / prefill / decode) with full in/out
     shardings on the requested mesh,
  2. ``.lower().compile()`` — success proves the distribution config is
     coherent (shardings consistent, collectives supported, memory fits),
  3. records ``memory_analysis()`` + ``cost_analysis()`` + the collective
     schedule parsed from the partitioned HLO into a per-cell JSON artifact
     consumed by EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""

import argparse
import json
import pathlib
import time
import traceback

import jax  # noqa: F401  (imported before repro modules: XLA_FLAGS is set)

from repro.analysis import roofline as rf
from repro.configs import ARCH_IDS, SHAPES, SHAPES_BY_NAME, get_config, shape_applies
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, lower_cell


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             save_hlo: bool = False, layout: str = "tp", cfg=None) -> dict:
    shape = SHAPES_BY_NAME[shape_name]
    cfg = cfg or get_config(arch)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}"
    if layout != "tp":
        cell_id += f"__{layout}"
    ok, why = shape_applies(cfg, shape)
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": why}
        (out_dir / f"{cell_id}.json").write_text(json.dumps(rec, indent=2))
        print(f"[skip] {cell_id}: {why}")
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    try:
        cell = build_cell(arch, shape, mesh, cfg=cfg, layout=layout)
        with mesh:
            lowered = lower_cell(cell)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        mem_stats = {}
        if mem is not None:
            for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                         "temp_size_in_bytes", "generated_code_size_in_bytes",
                         "alias_size_in_bytes"):
                v = getattr(mem, attr, None)
                if v is not None:
                    mem_stats[attr] = int(v)
            live = (mem_stats.get("argument_size_in_bytes", 0)
                    + mem_stats.get("temp_size_in_bytes", 0)
                    + mem_stats.get("output_size_in_bytes", 0)
                    - mem_stats.get("alias_size_in_bytes", 0))
            mem_stats["bytes_per_device"] = live
            mem_stats["peak"] = live
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        roof = rf.analyze(arch, shape_name, mesh_name, chips, cost, hlo,
                          rf.model_flops_for(cfg, shape), mem_stats)
        rec = {
            "cell": cell_id, "status": "ok",
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "chips": chips,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "memory": mem_stats,
            "cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")
                     if k in cost},
            "roofline": roof.to_json(),
        }
        if save_hlo:
            (out_dir / f"{cell_id}.hlo.txt").write_text(hlo)
        print(f"[ok]   {cell_id}: lower {t_lower:.0f}s compile {t_compile:.0f}s "
              f"flops/dev={roof.hlo_gflops:.0f}G "
              f"bottleneck={roof.bottleneck}")
    except Exception as e:  # noqa: BLE001 - record the failure, keep sweeping
        rec = {"cell": cell_id, "status": "error",
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
        print(f"[FAIL] {cell_id}: {type(e).__name__}: {e}")
    (out_dir / f"{cell_id}.json").write_text(json.dumps(rec, indent=2))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument(
        "--layout", default="tp", choices=["tp", "cp", "fsdp", "kvq", "noFSDP"]
    )
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = (
        [s.name for s in SHAPES]
        if (args.all or args.shape is None)
        else [args.shape]
    )
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_ok = n_fail = n_skip = 0
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
                suffix = "" if args.layout == "tp" else f"__{args.layout}"
                art = out_dir / f"{arch}__{shape}__{mesh_name}{suffix}.json"
                if args.skip_existing and art.exists():
                    rec = json.loads(art.read_text())
                    if rec.get("status") in ("ok", "skipped"):
                        continue
                rec = run_cell(arch, shape, multi_pod, out_dir,
                               save_hlo=args.save_hlo, layout=args.layout)
                s = rec["status"]
                n_ok += s == "ok"
                n_fail += s == "error"
                n_skip += s == "skipped"
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
