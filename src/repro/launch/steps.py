"""Step builders + abstract input specs for every (arch × shape) cell.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation).  ``build_cell`` assembles the jitted step
with in/out shardings for a given mesh — used by the multi-pod dry-run, the
trainer and the benchmarks alike.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.dist import partitioning as parts
from repro.dist.sharding import ShardingRules, use_rules
from repro.models import transformer as tfm
from repro.models.config import ModelConfig, ShapeConfig
from repro.train import optimizer as opt_lib


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract batch for one cell (the modality frontends are stubs)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    specs: Dict[str, Any] = {}
    s_text = S
    if cfg.family == "vlm" and cfg.n_prefix:
        s_text = S - cfg.n_prefix
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_prefix, cfg.d_model), bf16)
    if cfg.family == "audio":
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.n_ctx, cfg.encoder.d_model or cfg.d_model), bf16)
    specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
    return specs


def abstract_params(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: tfm.init_params(cfg, k), key)


def abstract_opt_state(params_shape):
    return jax.eval_shape(opt_lib.init, params_shape)


def abstract_decode_state(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: tfm.init_decode_state(cfg, shape.global_batch, shape.seq_len))


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: opt_lib.OptimizerConfig):
    def train_step(params, opt_state, batch):
        def loss_of(p):
            return tfm.loss_fn(p, cfg, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params)
        new_params, new_opt, om = opt_lib.apply(opt_cfg, params, grads,
                                                opt_state)
        return new_params, new_opt, {"loss": loss, **metrics, **om}
    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        h, _ = tfm.forward(params, cfg, batch["tokens"],
                           prefix_embeds=batch.get("prefix_embeds"),
                           encoder_frames=batch.get("encoder_frames"))
        return tfm.unembed(params, cfg, h[:, -1:])
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, state, batch):
        return tfm.decode_step(params, cfg, state, batch["tokens"])
    return serve_step


# ---------------------------------------------------------------------------
# Cell assembly (mesh + shardings + jit)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeConfig
    rules: ShardingRules
    jitted: Any
    args: Tuple[Any, ...]        # abstract args for .lower()
    kind: str


def rules_for(mesh, shape: ShapeConfig, layout: str = "tp") -> ShardingRules:
    """Layouts:
      'tp'  — baseline: Megatron-style TP over 'model' + DP/FSDP over 'data'
      'cp'  — beyond-paper: context parallelism over 'model' (activations
              sequence-sharded; no per-layer TP all-reduces; weights FSDP) —
              motivated by the v5e napkin math in EXPERIMENTS.md §Perf.
      'fsdp' — beyond-paper: batch over every mesh axis (1 row/device),
              parameters fully sharded, per-layer weight gathers (ZeRO-3).
    """
    overrides = {}
    if shape.kind == "decode":
        # flash-decoding SP: shard the KV-cache sequence over 'model'
        overrides["kv_seq"] = "model"
        if layout == "noFSDP":
            # serving holds weights TP-sharded only: no per-layer FSDP
            # gathers in the step (§Perf iteration 3)
            overrides["embed_p"] = None
    if layout == "cp" and shape.kind in ("train", "prefill"):
        overrides.update({
            "heads": None, "kv_heads": None, "ff": None,
            "seq": "model", "act_seq": "model",
        })
    if layout == "fsdp" and shape.kind in ("train", "prefill"):
        axes = tuple(a for a in ("pod", "data", "model")
                     if a in mesh.axis_names)
        overrides.update({
            "batch": axes, "heads": None, "kv_heads": None, "ff": None,
            "vocab": None, "embed_p": ("data", "model"),
        })
    return ShardingRules(mesh, overrides)


def build_cell(arch: str, shape: ShapeConfig, mesh,
               opt_cfg: Optional[opt_lib.OptimizerConfig] = None,
               cfg: Optional[ModelConfig] = None,
               layout: str = "tp") -> Cell:
    cfg = cfg or get_config(arch)
    rules = rules_for(mesh, shape, layout)
    batch = input_specs(cfg, shape)
    p_shape = abstract_params(cfg)
    p_shard = parts.param_shardings(rules, p_shape)
    b_shard = parts.batch_shardings(rules, batch)
    rep = parts.replicated(rules)

    with use_rules(rules):
        if shape.kind == "train":
            opt_cfg = opt_cfg or opt_lib.OptimizerConfig()
            o_shape = abstract_opt_state(p_shape)
            o_shard = opt_lib.OptState(
                step=rep,
                m=parts.param_shardings(rules, o_shape.m),
                v=parts.param_shardings(rules, o_shape.v))
            fn = make_train_step(cfg, opt_cfg)
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard,
                               jax.tree.map(lambda _: rep, {
                                   "loss": 0, "xent": 0, "aux": 0,
                                   "tokens": 0, "grad_norm": 0, "lr": 0})),
                donate_argnums=(0, 1))
            args = (p_shape, o_shape, batch)
        elif shape.kind == "prefill":
            fn = make_prefill_step(cfg)
            logits_shape = (shape.global_batch, 1, cfg.vocab)
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, b_shard),
                out_shardings=rules.sharding(logits_shape, "batch", None,
                                             "vocab"))
            args = (p_shape, batch)
        else:  # decode
            s_shape = abstract_decode_state(cfg, shape)
            s_shard = parts.state_shardings(rules, s_shape)
            fn = make_decode_step(cfg)
            logits_shape = (shape.global_batch, 1, cfg.vocab)
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, s_shard, b_shard),
                out_shardings=(rules.sharding(logits_shape, "batch", None,
                                              "vocab"), s_shard),
                donate_argnums=(1,))
            args = (p_shape, s_shape, batch)
    return Cell(cfg=cfg, shape=shape, rules=rules, jitted=jitted,
                args=args, kind=shape.kind)


def lower_cell(cell: Cell):
    with use_rules(cell.rules):
        return cell.jitted.lower(*cell.args)
