"""Serving launcher: batched generation with the paged (optionally
int8-semantic-quantized) KV cache.

CPU smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --smoke \\
      --batch 4 --max-new 32 [--kv-quant]
Dry-run of the production decode cell:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b \\
      --shape decode_32k --dry-run [--kv-quant]
"""

import argparse
import dataclasses
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 semantic KV pages (paper §4.2 as quantizer)")
    ap.add_argument("--dry-run", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import pathlib
        from repro.configs import get_config
        from repro.launch.dryrun import run_cell
        out = pathlib.Path("results/dryrun")
        out.mkdir(parents=True, exist_ok=True)
        cfg = get_config(args.arch)
        layout = "tp"
        if args.kv_quant:
            cfg = dataclasses.replace(cfg, kv_quant=True)
            layout = "kvq"
        rec = run_cell(args.arch, args.shape, False, out, layout=layout,
                       cfg=cfg)
        print(json.dumps(rec.get("roofline", rec), indent=2, default=str))
        return

    import numpy as np
    import jax
    from repro.configs import reduced_config
    from repro.models import transformer as tfm
    from repro.serve.engine import Engine

    cfg = reduced_config(args.arch)
    if args.kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_len=args.prompt_len + args.max_new + 8)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    res = eng.generate(prompts, max_new=args.max_new,
                       temperature=args.temperature)
    dt = time.perf_counter() - t0
    n = args.batch * args.max_new
    print(f"generated {n} tokens in {dt:.2f}s "
          f"({1e3 * dt / n:.1f} ms/token on this host)")
    print("first sequence:", res.tokens[0].tolist())


if __name__ == "__main__":
    main()
