"""Training launcher.

CPU smoke (reduced config, host mesh):
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --smoke \\
      --steps 50 --ckpt-dir /tmp/ckpt

Pod-scale configuration (on a real v5e pod this process runs per host; here
the same flags drive the dry-run meshes):
  PYTHONPATH=src python -m repro.launch.train --arch nemotron-4-15b \\
      --shape train_4k --layout cp --dry-run
"""

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--layout", default="tp", choices=["tp", "cp", "fsdp"])
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape on the host mesh")
    ap.add_argument("--compress-ckpt", action="store_true")
    ap.add_argument("--watchdog-s", type=float, default=0.0)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile only (production mesh)")
    args = ap.parse_args()

    if args.dry_run:
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import pathlib
        from repro.launch.dryrun import run_cell
        out = pathlib.Path("results/dryrun")
        out.mkdir(parents=True, exist_ok=True)
        rec = run_cell(args.arch, args.shape, False, out, layout=args.layout)
        print(json.dumps(rec.get("roofline", rec), indent=2, default=str))
        return

    from repro.configs import reduced_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.config import ShapeConfig
    from repro.train.loop import Trainer, TrainerConfig

    tc = TrainerConfig(arch=args.arch, shape=args.shape, steps=args.steps,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       layout=args.layout, compress_ckpt=args.compress_ckpt,
                       watchdog_s=args.watchdog_s)
    cfg = shape = mesh = None
    if args.smoke:
        cfg = reduced_config(args.arch)
        shape = ShapeConfig("smoke", seq_len=64, global_batch=8, kind="train")
        mesh = make_host_mesh()
    else:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
    tr = Trainer(tc, mesh, cfg=cfg, shape=shape)
    out = tr.run(resume=True)
    for m in tr.metrics_log:
        print(json.dumps(m))
    print(f"done: {out['steps_done']} steps in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
