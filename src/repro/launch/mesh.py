"""Production meshes.  Defined as functions so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax call).

Single pod: (data=16, model=16) = 256 chips (TPU v5e-256 pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips across DCN.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1-device mesh for CPU smoke runs (same code path, no sharding)."""
    return jax.make_mesh((1, 1), ("data", "model"))
