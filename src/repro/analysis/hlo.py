"""Mini HLO analyzer: trip-count-aware FLOPs / bytes / collective accounting.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**, which
under-reports scanned-layer models by the trip count (verified empirically in
tests).  This analyzer parses the *partitioned, post-optimization* HLO text:

* splits the module into computations and builds a call graph
  (while/fusion/call/conditional edges);
* extracts while trip counts from the condition computation's bound
  (``compare(iv, constant(N))``) and propagates execution multipliers from
  ENTRY;
* FLOPs: every ``dot`` counts 2·|out|·|contraction| × multiplier
  (convolutions are approximated the same way via output × kernel size);
* HBM bytes: Σ (operand + result bytes) of memory-level instructions
  (fusion *call sites*, not fusion internals — post-fusion HLO operands and
  results approximate actual HBM traffic);
* collectives: ring-cost wire bytes per device × multiplier.

All numbers are per-device (the module is the per-device SPMD program).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_list_bytes(text: str) -> int:
    return sum(_one_shape_bytes(m) for m in _SHAPE_RE.finditer(text))


def _one_shape_bytes(m) -> int:
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _shape_dims(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    result_types: str           # full text before the op
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    defs: Dict[str, str]        # %name -> result type text


_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# opcode = first `word(` token after the result types (type text never
# produces such a token: types look like f32[128,256]{1,0} or tuples)
_OPCODE_RE = re.compile(r"([a-z][a-z0-9_\-]*)\(")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            s = line.strip()
            if s.endswith("{") and "->" in s:
                m = _COMP_HEAD.match(s)
                if m:
                    cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        om = _OPCODE_RE.search(rhs)
        if not om:
            continue
        rtypes = rhs[:om.start()]
        opcode = om.group(1)
        inst = Instruction(name=name, opcode=opcode, result_types=rtypes,
                           line=line.strip())
        cur.instructions.append(inst)
        cur.defs[name] = rtypes
    return comps


_CALL_ATTRS = (
    ("while", ("body", "condition")),
    ("fusion", ("calls",)),
    ("call", ("to_apply",)),
    ("conditional", ("branch_computations", "true_computation",
                     "false_computation")),
    ("custom-call", ("called_computations",)),
    ("sort", ()),           # comparator: negligible
    ("reduce", ()),         # to_apply: negligible
    ("scatter", ()),
    ("map", ()),
)


def _called_comps(line: str, attrs: Tuple[str, ...]) -> List[str]:
    out: List[str] = []
    for a in attrs:
        m = re.search(rf"{a}=%?([\w\.\-]+)", line)
        if m:
            out.append(m.group(1))
        m = re.search(rf"{a}=\{{([^}}]*)\}}", line)
        if m:
            out += [s.strip().lstrip("%") for s in m.group(1).split(",")]
    return out


def _while_trip_count(cond: Computation,
                      comps: Dict[str, "Computation"]) -> int:
    """Trip count from the loop bound compare(iv, constant(N)).

    Post-optimization the compare sits inside a wrapped fusion; we resolve
    the compare operands through the fusion call back to constants defined
    in the condition computation.
    """
    consts: Dict[str, int] = {}
    for inst in cond.instructions:
        m = re.search(r"constant\((\d+)\)", inst.line)
        if m and "s32" in inst.result_types:
            consts[inst.name] = int(m.group(1))

    def from_compare(comp: Computation, operand_map: Dict[str, str]) -> Optional[int]:
        for inst in comp.instructions:
            if inst.opcode == "compare":
                for o in _operand_names(inst):
                    o = operand_map.get(o, o)
                    if o in consts and consts[o] > 1:
                        return consts[o]
        return None

    v = from_compare(cond, {})
    if v:
        return v
    # look through fusion/call wrappers, mapping params to call operands
    for inst in cond.instructions:
        if inst.opcode not in ("fusion", "call"):
            continue
        m = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", inst.line)
        if not m or m.group(1) not in comps:
            continue
        inner = comps[m.group(1)]
        call_ops = _operand_names(inst)
        pmap: Dict[str, str] = {}
        for iinst in inner.instructions:
            if iinst.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", iinst.line)
                if pm and int(pm.group(1)) < len(call_ops):
                    pmap[iinst.name] = call_ops[int(pm.group(1))]
        v = from_compare(inner, pmap)
        if v:
            return v
    return 1


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    dot_flops_by_comp: Dict[str, float] = dataclasses.field(default_factory=dict)
    trip_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_counts": self.collective_counts,
            "collective_bytes": self.collective_bytes,
            "trip_counts": self.trip_counts,
        }


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota", "partition-id",
                   "replica-id", "while", "conditional",
                   "optimization-barrier", "copy-start", "copy-done"}


def _dot_flops(inst: Instruction, defs: Dict[str, str]) -> float:
    out = _shape_dims(inst.result_types)
    if out is None:
        return 0.0
    _, out_dims = out
    m = re.search(r"\(([^)]*)\)", inst.line.split("=", 1)[1])
    ops = re.findall(r"%([\w\.\-]+)", m.group(1)) if m else []
    lhs_shape = _shape_dims(defs.get(ops[0], "")) if ops else None
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
    contraction = 1
    if lhs_shape and cdims and cdims.group(1):
        for ci in cdims.group(1).split(","):
            i = int(ci)
            if i < len(lhs_shape[1]):
                contraction *= lhs_shape[1][i]
    n_out = 1
    for d in out_dims:
        n_out *= d
    return 2.0 * n_out * contraction


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def _operand_names(inst: Instruction) -> List[str]:
    m = re.search(r"\(([^)]*)\)", inst.line.split("=", 1)[1])
    if not m:
        return []
    return re.findall(r"%([\w\.\-]+)", m.group(1))


def _operand_bytes(inst: Instruction, defs: Dict[str, str]) -> int:
    return sum(_shape_list_bytes(defs.get(o, ""))
               for o in _operand_names(inst))


@dataclasses.dataclass
class FusionMemInfo:
    slice_params: Dict[int, int]       # param idx -> bytes actually read
    dus_update_bytes: int = 0          # in-place writes (update operands)
    dus_buffer_params: frozenset = frozenset()  # aliased buffer param idxs
    has_dus: bool = False


def _fusion_mem_info(comp: Computation) -> FusionMemInfo:
    """What a fusion actually reads/writes: dynamic-slices read only the
    slice; dynamic-update-slices write only the update (the buffer operand
    is aliased in place)."""
    param_of: Dict[str, int] = {}
    for inst in comp.instructions:
        if inst.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", inst.line)
            if m:
                param_of[inst.name] = int(m.group(1))
    slice_params: Dict[int, int] = {}
    dus_updates = 0
    dus_buffers = set()
    has_dus = False
    for inst in comp.instructions:
        if inst.opcode in ("dynamic-slice", "gather", "slice"):
            ops = _operand_names(inst)
            if ops and ops[0] in param_of:
                idx = param_of[ops[0]]
                slice_params[idx] = max(slice_params.get(idx, 0),
                                        _shape_list_bytes(inst.result_types))
        elif inst.opcode == "dynamic-update-slice":
            has_dus = True
            ops = _operand_names(inst)
            if len(ops) > 1:
                dus_updates += _shape_list_bytes(comp.defs.get(ops[1], ""))
                if ops[0] in param_of:
                    dus_buffers.add(param_of[ops[0]])
    return FusionMemInfo(slice_params=slice_params,
                         dus_update_bytes=dus_updates,
                         dus_buffer_params=frozenset(dus_buffers),
                         has_dus=has_dus)


def _memory_bytes(inst: Instruction, defs: Dict[str, str],
                  fusion_mem: Dict[str, FusionMemInfo]) -> int:
    """Approximate HBM traffic of one memory-level instruction.

    Slicing ops read only the slice; in-place updates write only the
    update; broadcasts read a small input.  Fusions are charged what their
    subcomputation actually touches (slices / in-place updates).
    """
    op = inst.opcode
    res = _shape_list_bytes(inst.result_types)
    if op in ("dynamic-slice", "slice", "gather"):
        return 2 * res
    if op == "dynamic-update-slice":
        ops = _operand_names(inst)
        upd = _shape_list_bytes(defs.get(ops[1], "")) if len(ops) > 1 else 0
        return 2 * upd
    if op == "scatter":
        ops = _operand_names(inst)
        upd = _shape_list_bytes(defs.get(ops[-1], "")) if ops else 0
        return 2 * upd
    if op in ("broadcast", "iota"):
        return res
    if op == "fusion":
        m = re.search(r"calls=%?([\w\.\-]+)", inst.line)
        info = fusion_mem.get(m.group(1)) if m else None
        if info is None:
            return _operand_bytes(inst, defs) + res
        total = 0
        for i, o in enumerate(_operand_names(inst)):
            if i in info.dus_buffer_params:
                continue  # aliased in place
            b = _shape_list_bytes(defs.get(o, ""))
            if i in info.slice_params:
                b = min(b, info.slice_params[i])
            total += b
        if info.has_dus:
            total += 2 * info.dus_update_bytes
        else:
            total += res
        return total
    return _operand_bytes(inst, defs) + res


def analyze_hlo(text: str, default_group: int) -> HloStats:
    comps = parse_module(text)
    # entry = computation not called by anyone, or named ENTRY (first parsed
    # with 'ENTRY' marker was lost; detect by call graph)
    called = set()
    calls: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    trip_of_body: Dict[str, int] = {}
    fusion_bodies = set()
    for cname, comp in comps.items():
        for inst in comp.instructions:
            if inst.opcode == "while":
                body = _called_comps(inst.line, ("body",))
                cond = _called_comps(inst.line, ("condition",))
                trips = 1
                if cond and cond[0] in comps:
                    trips = _while_trip_count(comps[cond[0]], comps)
                for b in body + cond:
                    if b in comps:
                        calls[cname].append((b, float(trips)))
                        called.add(b)
                        trip_of_body[b] = trips
            else:
                for attr in ("calls", "to_apply", "branch_computations",
                             "true_computation", "false_computation",
                             "called_computations"):
                    for b in _called_comps(inst.line, (attr,)):
                        if b in comps:
                            mult = 1.0
                            calls[cname].append((b, mult))
                            called.add(b)
                            if inst.opcode == "fusion":
                                fusion_bodies.add(b)
    roots = [c for c in comps if c not in called]
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    for r in roots:
        mult[r] = 1.0
    fusion_mem = {c: _fusion_mem_info(comps[c]) for c in fusion_bodies}
    # propagate multipliers (graph is a DAG; iterate to fixpoint)
    for _ in range(len(comps)):
        changed = False
        for cname in comps:
            if mult[cname] <= 0:
                continue
            for (b, m) in calls[cname]:
                want = mult[cname] * m
                if want > mult[b]:
                    mult[b] = want
                    changed = True
        if not changed:
            break

    st = HloStats()
    st.trip_counts = trip_of_body
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        comp_flops = 0.0
        for inst in comp.instructions:
            if inst.opcode in ("dot", "convolution"):
                comp_flops += _dot_flops(inst, comp.defs)
            kind = next((k for k in _COLLECTIVES
                         if inst.opcode in (k, k + "-start")), None)
            if kind is not None:
                n = _group_size(inst.line, default_group)
                ins = _operand_bytes(inst, comp.defs)
                outs = _shape_list_bytes(inst.result_types)
                if kind == "all-gather":
                    b = max(outs - ins, 0)
                elif kind == "reduce-scatter":
                    b = max(ins - outs, 0)
                elif kind == "all-reduce":
                    b = 2.0 * (n - 1) / max(n, 1) * ins
                elif kind == "all-to-all":
                    b = (n - 1) / max(n, 1) * ins
                else:
                    b = ins
                st.collective_counts[kind] = st.collective_counts.get(kind, 0) + m
                st.collective_bytes[kind] = st.collective_bytes.get(kind, 0.0) + b * m
                st.collective_wire_bytes += b * m
            if (
                cname not in fusion_bodies
                and inst.opcode not in _SKIP_BYTES_OPS
                and not inst.opcode.endswith("-done")
            ):
                st.bytes_accessed += m * _memory_bytes(inst, comp.defs,
                                                       fusion_mem)
        if comp_flops:
            st.dot_flops_by_comp[cname] = comp_flops * m
            st.flops += comp_flops * m
    return st
