"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh) cell, in seconds (TPU v5e targets):

    compute    = HLO_FLOPs / (chips × 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips × 819e9 B/s HBM)
    collective = Σ per-op cost(bytes, algorithm) / 49.5e9 B/s per-link ICI

FLOPs/bytes come from ``compiled.cost_analysis()`` (whole-program totals);
collective bytes are parsed from the *partitioned* HLO text — XLA's
cost analysis does not attribute collective traffic.  Per-op wire cost uses
ring-algorithm accounting on the per-device (post-SPMD) shapes:

    all-gather:         out_bytes - in_bytes   received per device
    reduce-scatter:     in_bytes - out_bytes
    all-reduce:         2 × (N-1)/N × bytes
    all-to-all:         (N-1)/N × bytes
    collective-permute: bytes
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

# TPU v5e hardware constants (task statement)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape like ``bf16[16,1024]`` (1 for scalars)."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def _result_shapes(line: str) -> List[str]:
    """Shapes on the LHS of an HLO instruction line."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return []
    rhs = lhs[1].strip()
    # result type precedes the op name: `bf16[8,128]{1,0} all-gather(...)`
    m = re.match(r"\(?([^()]*?)\)?\s*(%?[\w-]+)\(", rhs)
    if not m:
        return []
    types = m.group(1)
    return re.findall(r"\w+\[[\d,]*\]", types)


def _operand_shapes(line: str) -> List[str]:
    """Shapes of the operands (inside the call parens)."""
    m = re.search(r"\(([^)]*)\)", line.split(" = ", 1)[1])
    if not m:
        return []
    return re.findall(r"\w+\[[\d,]*\]", m.group(1))


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota format [ngroups, group_size]
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    wire_bytes: Dict[str, float]     # per-device bytes on the wire
    total_wire_bytes: float

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    counts: Dict[str, int] = {}
    wire: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        stripped = line.strip()
        kind = None
        for k in _COLLECTIVE_KINDS:
            if re.search(rf"[\s(]({k}(-start|-done)?)\(", " " + stripped):
                kind = k
                break
        if kind is None or f"{kind}-done" in stripped:
            continue  # count -start once, skip -done
        n = _group_size(stripped, default_group)
        outs = sum(_shape_bytes(s) for s in _result_shapes(stripped))
        ins = sum(_shape_bytes(s) for s in _operand_shapes(stripped))
        if kind == "all-gather":
            b = max(outs - ins, 0)
        elif kind == "reduce-scatter":
            b = max(ins - outs, 0)
        elif kind == "all-reduce":
            b = 2.0 * (n - 1) / max(n, 1) * ins
        elif kind == "all-to-all":
            b = (n - 1) / max(n, 1) * ins
        else:  # collective-permute
            b = ins
        counts[kind] = counts.get(kind, 0) + 1
        wire[kind] = wire.get(kind, 0.0) + b
    return CollectiveStats(counts=counts, wire_bytes=wire,
                           total_wire_bytes=sum(wire.values()))


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float            # whole-program
    hlo_gbytes: float            # whole-program HBM traffic
    wire_gbytes_per_chip: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_gflops: float          # 6*N*D (or 6*N_active*D)
    useful_flops_frac: float     # model/hlo
    collectives: Dict
    bytes_per_device: Optional[float] = None
    peak_memory_per_device: Optional[float] = None

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            cost: Dict, hlo_text: str, model_flops: float,
            memory_stats: Optional[Dict] = None) -> Roofline:
    """All totals are per-device: the compiled module is the SPMD program
    for one device, and the trip-count-aware analyzer (analysis.hlo) walks
    it with while-loop multipliers (XLA's cost_analysis counts loop bodies
    once — verified in tests)."""
    from .hlo import analyze_hlo
    st = analyze_hlo(hlo_text, default_group=chips)
    flops = st.flops            # per-device
    byts = st.bytes_accessed    # per-device
    t_comp = flops / PEAK_FLOPS
    t_mem = byts / HBM_BW
    t_coll = st.collective_wire_bytes / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    model_per_chip = model_flops / chips
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=byts / 1e9,
        wire_gbytes_per_chip=st.collective_wire_bytes / 1e9,
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=max(terms, key=terms.get),
        model_gflops=model_flops / 1e9,
        useful_flops_frac=(model_per_chip / flops) if flops else 0.0,
        collectives={"counts": st.collective_counts,
                     "wire_bytes": st.collective_bytes,
                     "total_wire_bytes": st.collective_wire_bytes,
                     "trip_counts": st.trip_counts},
        bytes_per_device=(memory_stats or {}).get("bytes_per_device"),
        peak_memory_per_device=(memory_stats or {}).get("peak"),
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference forward passes
    (per step for decode: D = global_batch tokens)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
