"""Columnar scan engine: predicate pushdown on compressed code streams.

See DESIGN.md §8.  Public surface:

- :class:`Eq` / :class:`In` / :class:`Range` — value-space predicates
- :func:`scan_table` — pushdown scan of one ``CompressedTable``
- :func:`match_row` / :func:`match_all` — the value-space reference
  semantics every lowered path must agree with
"""

from .engine import ScanResult, ScanStats, scan_table
from .predicates import Eq, In, Predicate, Range, match_all, match_row

__all__ = [
    "Eq",
    "In",
    "Range",
    "Predicate",
    "ScanResult",
    "ScanStats",
    "scan_table",
    "match_all",
    "match_row",
]
