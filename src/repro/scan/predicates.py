"""Value-space predicates for the columnar scan engine (DESIGN.md §8).

Predicates are declared against *decoded* column values — the semantics are
exactly "decode every row, then filter".  The engine (`repro.scan.engine`)
lowers them into code-space forms per plan version when it can (category-id
compares, quantized-step intervals) and falls back to these value-space
matchers for pending rows, slow blocks, and non-lowerable versions, so both
paths agree by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

_MISSING = object()


@dataclasses.dataclass(frozen=True)
class Eq:
    """``row[column] == value``."""

    column: str
    value: Any


@dataclasses.dataclass(frozen=True)
class In:
    """``row[column] in values``."""

    column: str
    values: Tuple[Any, ...]

    def __init__(self, column: str, values: Sequence[Any]) -> None:
        object.__setattr__(self, "column", column)
        object.__setattr__(self, "values", tuple(values))


@dataclasses.dataclass(frozen=True)
class Range:
    """``lo <= row[column] <= hi`` (inclusive; ``None`` bounds are open)."""

    column: str
    lo: Optional[Any] = None
    hi: Optional[Any] = None


Predicate = Any  # Eq | In | Range


def match_row(pred: Predicate, row: Dict[str, Any]) -> bool:
    """Value-space evaluation of one predicate against a decoded row.

    Incomparable values (``TypeError``) and missing columns never match —
    the same convention the code-space lowerings implement by dropping
    out-of-vocabulary literals.
    """
    v = row.get(pred.column, _MISSING)
    if v is _MISSING:
        return False
    if isinstance(pred, Eq):
        try:
            return bool(v == pred.value)
        except TypeError:
            return False
    if isinstance(pred, In):
        try:
            return v in pred.values
        except TypeError:
            return False
    if isinstance(pred, Range):
        try:
            if pred.lo is not None and v < pred.lo:
                return False
            if pred.hi is not None and v > pred.hi:
                return False
        except TypeError:
            return False
        return True
    raise TypeError(f"unknown predicate type {type(pred).__name__}")


def match_all(preds: Sequence[Predicate], row: Dict[str, Any]) -> bool:
    """Conjunction of ``preds`` over one row (empty = match)."""
    return all(match_row(p, row) for p in preds)
