"""Columnar scan over the compressed CSR code arena (DESIGN.md §8).

``scan_table`` evaluates a predicate conjunction against a
:class:`~repro.core.blitzcrank.CompressedTable` without materializing
non-matching rows:

1. **Zone prune** — numeric predicates test chunked min/max zone maps
   (raw-value bounds widened by the plan's quantization slack) and drop
   whole blocks before any code is touched.
2. **Code-space eval** — per plan version, predicates lower to category-id
   sets and quantized-step intervals.  A single categorical predicate on
   slot 0 evaluates straight off the raw arena through the coder's LUT;
   anything else decodes only the slot *prefix* the predicates name.
   Spilled blocks are read through (CRC-checked) without promotion, so an
   OLAP scan never evicts the OLTP hot set.
3. **Materialize survivors** — matching rows gather into one compact CSR
   and decode with ONE ``decode_select`` per plan version, reconstructing
   only the projected columns.

Slow blocks, non-lowerable versions, and pending rows fall back to
decode-then-filter with the same value-space matchers, so results are
bit-identical to the reference scan by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import telemetry
from repro.core.arena import ExtentCorruptionError, SpillCorruptionError
from repro.core.plan import (
    decode_select_prefix,
    lower_cat_ids,
    lower_cat_range_ids,
    lower_num_interval,
    num_q_of_syms,
    quantize_slack,
    scan_lowering,
    slot0_match_lut,
)

from .predicates import Eq, In, Predicate, Range, match_all

# Lowering outcomes for one (version, predicate-set) pair.
_FALLBACK = "fallback"  # can't lower every predicate: decode + filter
_IMPOSSIBLE = "impossible"  # no conforming row can match: skip fast blocks


class ScanStats:
    """Observability for one scan (accumulated across shards by callers).

    Backed by the shared telemetry registry (DESIGN.md §9): every field
    write flows its *delta* into the ``repro.scan.<field>`` counter, so
    the registry carries engine-wide scan totals while each instance
    keeps its per-scan view.  :meth:`merge` folds another instance's
    local values in WITHOUT touching the registry — the merged-in scan
    already registered its deltas when they happened, so cross-shard
    aggregation can never double-count globally (the old
    dataclass-``merge`` duplication risk).  The attribute API (reads,
    ``+=``, plain assignment) is unchanged; fields are thin properties.
    """

    _FIELDS = (
        "blocks_total",  # live candidate blocks before pruning
        "blocks_pruned",  # dropped by zone maps alone
        "blocks_lut",  # evaluated via the slot-0 LUT gather
        "rows_prefix_decoded",  # rows through the slot-prefix decode
        "blocks_fallback",  # full decode + value filter (no lowering)
        "blocks_scalar",  # slow blocks: per-block scalar decode
        "spilled_reads",  # cold blocks read through (not promoted)
        "rows_decoded",  # rows fully materialized
        "rows_matched",
        "versions",  # plan versions seen among fast blocks
    )
    __slots__ = ("_v",)

    def __init__(self, **fields: int) -> None:
        object.__setattr__(self, "_v", dict.fromkeys(self._FIELDS, 0))
        for name, value in fields.items():
            setattr(self, name, value)  # through the property: registers

    def merge(self, other: "ScanStats") -> None:
        """Fold ``other``'s local values in; registry-neutral (see class
        docstring)."""
        v, ov = self._v, other._v
        for f in self._FIELDS:
            v[f] += ov[f]

    def __repr__(self) -> str:  # dataclass-style, for test/debug output
        body = ", ".join(f"{f}={self._v[f]}" for f in self._FIELDS)
        return f"ScanStats({body})"

    def __eq__(self, other: object) -> bool:
        return isinstance(other, ScanStats) and self._v == other._v


def _scan_stat_property(name: str) -> property:
    # blitzlint: waive[BL002] -- repro.scan.<field> names are enumerated in the catalog and pinned by test_blitzlint
    counter = telemetry.counter(f"repro.scan.{name}")

    def _get(self: ScanStats) -> int:
        return self._v[name]

    def _set(self: ScanStats, value: int) -> None:
        delta = value - self._v[name]
        self._v[name] = value
        if delta:
            counter.add(delta)

    return property(_get, _set)


for _f in ScanStats._FIELDS:
    setattr(ScanStats, _f, _scan_stat_property(_f))
del _f


@dataclasses.dataclass
class ScanResult:
    ids: List[int]  # logical row ids, ascending
    rows: List[Dict[str, Any]]  # projected rows, parallel to ids
    stats: ScanStats


def _zone_bounds(
    pred: Predicate,
) -> Optional[Tuple[Optional[float], Optional[float]]]:
    """Value-space interval implied by ``pred``, or None (not prunable)."""
    try:
        if isinstance(pred, Eq):
            v = float(pred.value)
            return (v, v)
        if isinstance(pred, In):
            if not pred.values:
                return None
            vs = [float(v) for v in pred.values]
            return (min(vs), max(vs))
        if isinstance(pred, Range):
            lo = None if pred.lo is None else float(pred.lo)
            hi = None if pred.hi is None else float(pred.hi)
            if lo is None and hi is None:
                return None
            return (lo, hi)
    except (TypeError, ValueError):
        return None
    return None


def _column_slack(table, column: str) -> Optional[float]:
    """Worst-case |decoded - raw| for ``column`` across every plan version
    the table has ever encoded under; None disables pruning (a model with
    unbounded reconstruction error, or an unknown column)."""
    worst = 0.0
    for codec in table._codecs:
        m = codec.models.get(column)
        if m is None:
            return None
        s = quantize_slack(m)
        if s is None:
            return None
        worst = max(worst, s)
    return worst


def _lower_preds(plan: Any, preds: Sequence[Predicate]) -> Any:
    """Lower the conjunction into code-space forms for one plan version.

    Returns a list of lowered predicate tuples, ``_FALLBACK`` when any
    predicate has no code-space form under this plan, or ``_IMPOSSIBLE``
    when a lowered predicate provably matches no conforming (fast) row.
    """
    lowered = []
    for p in preds:
        ent = scan_lowering(plan, p.column)
        if ent is None:
            return _FALLBACK
        kind, cp, off = ent
        if kind == "cat":
            if isinstance(p, Eq):
                ids = lower_cat_ids(cp, [p.value])
            elif isinstance(p, In):
                ids = lower_cat_ids(cp, p.values)
            else:  # Range over a categorical vocabulary (small-int columns)
                ids = lower_cat_range_ids(cp, p.lo, p.hi)
                if ids is None:
                    return _FALLBACK
            if not ids.size:
                return _IMPOSSIBLE
            lowered.append(("cat", cp, off, ids))
        else:  # numeric two-level model: value intervals -> step intervals
            m = cp.m
            if isinstance(p, Range):
                try:
                    lo = None if p.lo is None else float(p.lo)
                    hi = None if p.hi is None else float(p.hi)
                except (TypeError, ValueError):
                    return _FALLBACK
                iv = lower_num_interval(m, lo, hi)
                if iv is None:
                    return _IMPOSSIBLE
                lowered.append(("numrange", cp, off, iv[0], iv[1]))
            else:
                values = [p.value] if isinstance(p, Eq) else list(p.values)
                qs: set = set()
                # blitzlint: waive[BL001] -- loops over predicate literals (a handful of constants), not table rows
                for v in values:
                    try:
                        fv = float(v)
                    except (TypeError, ValueError):
                        continue  # non-numeric literal can't match fast rows
                    iv = lower_num_interval(m, fv, fv)
                    if iv is not None:
                        qs.update(range(iv[0], iv[1] + 1))
                if not qs:
                    return _IMPOSSIBLE
                lowered.append(
                    ("numset", cp, off, np.asarray(sorted(qs), dtype=np.int64))
                )
    return lowered


def _read_spilled(table, blocks: np.ndarray, cache: Dict[int, np.ndarray]) -> None:
    """CRC-checked read-through of spilled ``blocks`` into ``cache``
    (block id -> uint16 codes) WITHOUT promoting them: the scan must not
    evict the transactional hot set or perturb the clock."""
    need = [int(b) for b in blocks if int(b) not in cache]
    if not need:
        return
    res = table._res
    offs = table._disk_off[need]
    lens = table._disk_len[need]
    try:
        payloads = res.disk.read_many_checked(offs, 2 * lens)
    except ExtentCorruptionError as e:
        bad = np.asarray(need, dtype=np.int64)[np.asarray(e.indices, dtype=np.int64)]
        table.note_quarantined_rows(len(e.indices))
        raise SpillCorruptionError(table._block2row[bad].tolist()) from e
    for b, p in zip(need, payloads):
        cache[b] = np.frombuffer(p, dtype=np.uint16)


def _compact_csr(
    table, blocks: np.ndarray, cache: Dict[int, np.ndarray]
) -> Tuple[np.ndarray, np.ndarray]:
    """Gather the code runs of ``blocks`` (resident from the arena, spilled
    from ``cache``) into one compact CSR ``(codes, offsets)``."""
    if table._res is not None:
        resident = table._resident[blocks]
    else:
        resident = np.ones(blocks.size, dtype=bool)
    lens = np.where(
        resident,
        table._offsets[blocks + 1] - table._offsets[blocks],
        (table._disk_len[blocks] if table._res is not None else 0),
    )
    offsets = np.zeros(blocks.size + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])
    codes = np.empty(int(offsets[-1]), dtype=np.uint16)
    # Bulk-gather the resident runs with one fancy index; spilled runs
    # copy from the read-through cache.
    rb = blocks[resident]
    if rb.size:
        starts = table._offsets[rb]
        rlens = lens[resident]
        dst = offsets[:-1][resident]
        total = int(rlens.sum())
        within = np.arange(total) - np.repeat(np.cumsum(rlens) - rlens, rlens)
        codes[np.repeat(dst, rlens) + within] = table.arena[
            np.repeat(starts, rlens) + within
        ]
    for j in np.nonzero(~resident)[0]:
        b = int(blocks[j])
        codes[offsets[j] : offsets[j + 1]] = cache[b]
    return codes, offsets


def _eval_lowered(
    table,
    plan,
    lowered,
    blocks: np.ndarray,
    cache: Dict[int, np.ndarray],
    stats: ScanStats,
) -> np.ndarray:
    """bool mask over ``blocks``: does the (single-tuple) block's row match
    every lowered predicate?  Evaluates on raw codes (slot-0 LUT) or a
    decoded slot prefix — never materializes a row."""
    if not lowered:
        return np.ones(blocks.size, dtype=bool)
    if table._res is not None:
        resident = table._resident[blocks]
    else:
        resident = np.ones(blocks.size, dtype=bool)
    spilled = blocks[~resident]
    if spilled.size:
        _read_spilled(table, spilled, cache)
        stats.spilled_reads += int(spilled.size)

    # Fast path: one categorical predicate on the first physical slot
    # compares raw stream codes through the coder's LUT — zero decode.
    if len(lowered) == 1 and lowered[0][0] == "cat" and lowered[0][2] == 0:
        lut = slot0_match_lut(plan.coders[0], lowered[0][3])
        if lut is not None:
            mask = np.zeros(blocks.size, dtype=bool)
            rb = blocks[resident]
            if rb.size:
                mask[resident] = lut[table.arena[table._offsets[rb]]]
            for j in np.nonzero(~resident)[0]:
                mask[j] = lut[cache[int(blocks[j])][0]]
            stats.blocks_lut += int(blocks.size)
            return mask

    # General path: decode just the slot prefix the predicates reach.
    upto = max(off + cp.n_slots for _, cp, off, *_ in lowered)
    syms = np.empty((blocks.size, upto), dtype=np.int64)
    rb = blocks[resident]
    if rb.size:
        syms[resident] = decode_select_prefix(
            plan, table.arena[: table.used], table.block_offsets, rb, upto
        )
    if spilled.size:
        codes, offsets = _compact_csr(table, spilled, cache)
        syms[~resident] = decode_select_prefix(
            plan, codes, offsets, np.arange(spilled.size), upto
        )
    stats.rows_prefix_decoded += int(blocks.size)
    mask = np.ones(blocks.size, dtype=bool)
    for ent in lowered:
        if ent[0] == "cat":
            _, cp, off, ids = ent
            mask &= np.isin(syms[:, off], ids)
        elif ent[0] == "numrange":
            _, cp, off, qlo, qhi = ent
            q = num_q_of_syms(cp, syms[:, off:])
            mask &= (q >= qlo) & (q <= qhi)
        else:  # numset
            _, cp, off, qs = ent
            q = num_q_of_syms(cp, syms[:, off:])
            mask &= np.isin(q, qs)
    return mask


def scan_table(
    table,
    predicates: Sequence[Predicate],
    columns: Optional[Sequence[str]] = None,
    backend: Optional[str] = None,
) -> ScanResult:
    """Predicate-pushdown scan of one :class:`CompressedTable`.

    Returns matching ``(logical row id, projected row)`` pairs in
    ascending id order — bit-identical to decoding every live row and
    filtering in value space.  Read-only: never flushes pending rows,
    faults in cold blocks, or advances the clock.
    """
    t0 = telemetry.clock()
    table.sanitize_boundary("scan_table")
    preds = list(predicates)
    stats = ScanStats()
    order = list(table.codec.order)
    known = set(order)
    for p in preds:
        if p.column not in known:
            raise KeyError(f"unknown predicate column: {p.column!r}")
    proj = order if columns is None else list(columns)
    unknown = set(proj) - known
    if unknown:
        raise KeyError(f"unknown columns: {sorted(unknown)}")
    pred_cols = [p.column for p in preds]
    hits: List[Tuple[int, Dict[str, Any]]] = []

    def _value_filtered(rid: int, row: Dict[str, Any]) -> None:
        if match_all(preds, row):
            hits.append((rid, {c: row[c] for c in proj}))

    if table.codec.block_tuples != 1:
        # Multi-tuple blocks: no indirection layer, decode-and-filter.
        rid = 0
        for b in range(table.n_blocks):
            rows = table.get_block(b)
            stats.blocks_scalar += 1
            stats.rows_decoded += len(rows)
            # blitzlint: waive[BL001] -- overlay rows are per-key Python dicts (delta layer contract)
            for r in rows:
                _value_filtered(rid, r)
                rid += 1
        # blitzlint: waive[BL001] -- pending tail rows are uncompressed dicts awaiting the next block flush
        for i, r in enumerate(table._pending):
            _value_filtered(table._rows_stored + i, r)
        stats.rows_matched = len(hits)
        telemetry.record("repro.scan.scan_table", t0)
        return ScanResult([h[0] for h in hits], [h[1] for h in hits], stats)

    nrows = table._rows_stored
    live = np.nonzero(table._row2block[:nrows] >= 0)[0]
    blks = table._row2block[live]
    stats.blocks_total = int(live.size)

    # -- phase 1: zone-map pruning (value space, version independent) ----
    if live.size:
        keep = np.ones(live.size, dtype=bool)
        for p in preds:
            bounds = _zone_bounds(p)
            if bounds is None:
                continue
            slack = _column_slack(table, p.column)
            if slack is None:
                continue
            m = table.zone_block_mask(p.column, bounds[0], bounds[1], slack=slack)
            if m is not None:
                keep &= m[blks]
        stats.blocks_pruned = int(live.size - np.count_nonzero(keep))
        live, blks = live[keep], blks[keep]

    # -- phase 2+3: per-version code-space eval, then one decode each ----
    cache: Dict[int, np.ndarray] = {}
    if live.size:
        fastm = table._fast[blks]
        vers = table._plan_ver[blks]
        scalar = ~fastm  # slow blocks always decode under their own codec
        for v in np.unique(vers[fastm]):
            sel = fastm & (vers == v)
            ids_v, blks_v = live[sel], blks[sel]
            codec_v = table._codecs[v]
            plan = codec_v.compile()
            lowered = _lower_preds(plan, preds) if plan is not None else _FALLBACK
            if lowered is _IMPOSSIBLE:
                continue  # fast => conforming => provably no match
            stats.versions += 1
            if lowered is _FALLBACK:
                survivors = np.arange(ids_v.size)
                stats.blocks_fallback += int(ids_v.size)
                need_cols = [c for c in order if c in set(proj) | set(pred_cols)]
            else:
                mask = _eval_lowered(table, plan, lowered, blks_v, cache, stats)
                survivors = np.nonzero(mask)[0]
                need_cols = proj
            if not survivors.size:
                continue
            if plan is None:  # uncompiled version: scalar decode per block
                for j in survivors.tolist():
                    stats.blocks_scalar += 1
                    stats.rows_decoded += 1
                    _value_filtered(int(ids_v[j]), table.get_block(int(blks_v[j]))[0])
                continue
            sblks = blks_v[survivors]
            if table._res is not None:
                sp = sblks[~table._resident[sblks]]
                if sp.size:
                    pre = len(cache)
                    _read_spilled(table, sp, cache)
                    stats.spilled_reads += len(cache) - pre
            codes, offsets = _compact_csr(table, sblks, cache)
            syms = plan.decode_select(
                codes,
                offsets,
                np.arange(sblks.size),
                backend=table._resolve_backend(backend, sblks.size, codec_v),
            )
            rows = plan.decode_syms_to_rows(syms, columns=need_cols)
            stats.rows_decoded += len(rows)
            if lowered is _FALLBACK:
                # blitzlint: waive[BL001] -- residual value filter evaluates on decoded row dicts (no code-space form)
                for rid, row in zip(ids_v[survivors].tolist(), rows):
                    _value_filtered(rid, row)
            else:
                # blitzlint: waive[BL001] -- residual value filter evaluates on decoded row dicts (no code-space form)
                for rid, row in zip(ids_v[survivors].tolist(), rows):
                    hits.append((rid, {c: row[c] for c in proj}))
        for j in np.nonzero(scalar)[0].tolist():
            stats.blocks_scalar += 1
            stats.rows_decoded += 1
            _value_filtered(int(live[j]), table.get_block(int(blks[j]))[0])

    # blitzlint: waive[BL001] -- pending tail is a per-row dict list by design; scans must see it
    for i, r in enumerate(table._pending):
        # Pending rows are value-filtered in place: the read path must not
        # flush (scan is concurrent with the transaction mix).
        _value_filtered(nrows + i, r)

    hits.sort(key=lambda h: h[0])
    stats.rows_matched = len(hits)
    telemetry.record("repro.scan.scan_table", t0)
    return ScanResult([h[0] for h in hits], [h[1] for h in hits], stats)
