"""Data pipeline: deterministic synthetic LM stream + compressed host store.

* :class:`SyntheticLM` — Zipf-distributed tokens with order-1 Markov
  structure (so models actually learn and compression has signal), generated
  *deterministically per (seed, step)* — resume after restart replays the
  exact batch sequence with no state files.
* :class:`CompressedExampleStore` — the paper's OLTP analogue on the
  training side (DESIGN.md §3.1): examples live Blitzcrank-compressed in
  host memory (token ids = categorical columns via the vectorized codec);
  the loader decompresses per batch.  Unseen token patterns stay encodable
  (semantic models, not static dictionaries).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.core.coders import DiscreteCoder, quantize_freqs
from repro.core.vectorized import decode_select, encode_batch


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab, 32768)
        self._v = v
        base = 1.0 / np.arange(1, v + 1) ** self.zipf_a
        self._p = base / base.sum()
        # order-1 structure: each token biases the next towards t+1 mod v
        self._shift = rng.integers(1, 64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.global_batch, self.seq_len
        toks = rng.choice(self._v, size=(B, S), p=self._p)
        # Markov overlay: with prob .5 next token = prev + shift (learnable)
        mask = rng.random((B, S)) < 0.5
        toks[:, 1:] = np.where(mask[:, 1:],
                               (toks[:, :-1] + self._shift) % self._v,
                               toks[:, 1:])
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        return {"tokens": toks.astype(np.int32),
                "labels": labels.astype(np.int32)}

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class CompressedExampleStore:
    """Blitzcrank-compressed in-memory example store with random access.

    Each example = one row of ``seq_len`` token columns; each column gets a
    categorical model fitted on a sample (semantic: unseen ids escape).
    """

    def __init__(self, sample_tokens: np.ndarray, vocab: int,
                 col_group: int = 1):
        # fit one shared model per column-position group from the sample
        S = sample_tokens.shape[1]
        counts = np.bincount(sample_tokens.reshape(-1), minlength=vocab)
        counts = counts.astype(np.float64) + 1e-3
        self.coder = DiscreteCoder(quantize_freqs(counts))
        self.S = S
        self.coders = [self.coder] * S
        self._codes = np.zeros(0, np.uint16)
        self._offsets = np.zeros(1, np.int64)

    def extend(self, tokens: np.ndarray) -> None:
        codes, offsets = encode_batch(tokens.astype(np.int64), self.coders)
        base = self._offsets[-1]
        self._codes = np.concatenate([self._codes, codes])
        self._offsets = np.concatenate(
            [self._offsets, offsets[1:] + base])

    def __len__(self) -> int:
        return self._offsets.size - 1

    def get_rows(self, rows: np.ndarray) -> np.ndarray:
        return decode_select(self._codes, self._offsets, self.coders,
                             rows).astype(np.int32)

    @property
    def nbytes(self) -> int:
        return int(self._codes.nbytes + self._offsets.nbytes)

    def raw_nbytes(self, itemsize: int = 4) -> int:
        return len(self) * self.S * itemsize


def batches_from_store(store: CompressedExampleStore, batch: int,
                       seed: int = 0, start_step: int = 0
                       ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    n = len(store)
    while True:
        rng = np.random.default_rng((seed, step))
        rows = rng.integers(0, n, batch)
        toks = store.get_rows(rows)
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        yield {"tokens": toks, "labels": labels.astype(np.int32)}
        step += 1
