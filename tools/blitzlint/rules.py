"""blitzlint rules BL001-BL007 (see DESIGN.md §10 for the catalog).

Every rule is narrow on purpose: each encodes one invariant this repo has
already paid for in debugging time (uint16 version-tag wrap, double-counted
telemetry, per-row slow paths hiding inside the batched engine) or will pay
for when the worker-per-shard scale-out lands (shared mutable globals,
out-of-owner mutation of shard state).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from .core import NAME_RE, Finding, LintContext, Rule, register

# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------


def _dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for nested Name/Attribute chains, else None."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def _functions(tree: ast.Module) -> Iterator[ast.AST]:
    """All function-like scopes, outermost first (module last)."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _walk_scope(scope: ast.AST) -> Iterator[ast.AST]:
    """Walk ``scope`` without descending into nested function scopes, so
    scope-sensitive rules visit every node exactly once."""
    stack: List[ast.AST] = [scope]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.append(child)


def _source_of(ctx: LintContext, node: ast.AST) -> str:
    lo = getattr(node, "lineno", 1) - 1
    hi = getattr(node, "end_lineno", lo + 1)
    return "\n".join(ctx.lines[lo:hi])


# ---------------------------------------------------------------------------
# BL001 — per-row Python loops in hot-path modules
# ---------------------------------------------------------------------------

ROWISH_NAMES = frozenset(
    {
        "rows",
        "vals",
        "values",
        "pvals",
        "records",
        "tuples",
        "ids",
        "keys",
        "pending",
        "_pending",
    }
)

_UNWRAP_CALLS = frozenset(
    {"enumerate", "zip", "reversed", "sorted", "list", "tuple", "iter"}
)


@register
class HotLoopRule(Rule):
    id = "BL001"
    title = "per-row Python loop in a hot-path module"
    rationale = (
        "The paper's batched fast path exists to eliminate value-at-a-time "
        "Python; a statement loop over rows in plan/blitzcrank/engine/store "
        "is either the scalar escape path (waive with the reason) or an "
        "accidental O(rows) regression.  Comprehensions are exempt: they are "
        "boundary conversions, not control flow."
    )

    def applies_to(self, ctx: LintContext) -> bool:
        return ctx.rel in ctx.config.hot_modules

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for scope in list(_functions(ctx.tree)) + [ctx.tree]:
            len_names = self._len_aliases(scope)
            for node in _walk_scope(scope):
                if isinstance(node, (ast.For, ast.AsyncFor)):
                    name = self._rowish(node.iter, len_names)
                    if name:
                        yield self.finding(
                            ctx,
                            node,
                            f"statement loop over per-row iterable {name!r} "
                            "(vectorize, or waive with the reason the scalar "
                            "path is required)",
                        )

    @staticmethod
    def _len_aliases(scope: ast.AST) -> Set[str]:
        """Names assigned ``len(<rowish>)`` in this scope (``n = len(rows)``)."""
        out: Set[str] = set()
        for node in _walk_scope(scope):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
                call = node.value
                if (
                    isinstance(call.func, ast.Name)
                    and call.func.id == "len"
                    and call.args
                    and isinstance(call.args[0], ast.Name)
                    and call.args[0].id in ROWISH_NAMES
                ):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            out.add(t.id)
        return out

    def _rowish(self, e: ast.AST, len_names: Set[str]) -> Optional[str]:
        if isinstance(e, ast.Name) and e.id in ROWISH_NAMES:
            return e.id
        if isinstance(e, ast.Attribute) and e.attr in ROWISH_NAMES:
            return _dotted(e) or e.attr
        if isinstance(e, ast.Call):
            fname = None
            if isinstance(e.func, ast.Name):
                fname = e.func.id
            if fname in _UNWRAP_CALLS:
                for a in e.args:
                    hit = self._rowish(a, len_names)
                    if hit:
                        return hit
                return None
            if fname == "range":
                for a in e.args:
                    # range(len(rows)) / range(n) with n = len(rows)
                    if isinstance(a, ast.Call) and isinstance(a.func, ast.Name):
                        if a.func.id == "len" and a.args:
                            inner = self._rowish(a.args[0], len_names)
                            if inner:
                                return inner
                    if isinstance(a, ast.Name) and a.id in len_names:
                        return a.id
                    # range(x.shape[0]) — a row-count loop over array x
                    if (
                        isinstance(a, ast.Subscript)
                        and isinstance(a.value, ast.Attribute)
                        and a.value.attr == "shape"
                    ):
                        return (_dotted(a.value) or "array") + "[0]"
                return None
            # rows.values() / rows.items() style
            if isinstance(e.func, ast.Attribute) and e.func.attr in (
                "values",
                "items",
                "keys",
            ):
                return self._rowish(e.func.value, len_names)
        return None


# ---------------------------------------------------------------------------
# BL002 — telemetry-name discipline
# ---------------------------------------------------------------------------

_TELEMETRY_FACTORIES = frozenset({"counter", "gauge", "histogram", "span", "record"})


@register
class TelemetryNameRule(Rule):
    id = "BL002"
    title = "telemetry name off-catalog or non-literal"
    rationale = (
        "Metric names are the join key for dashboards, the phase "
        "attribution report, and the regression gate; a typo silently "
        "forks a series.  Every literal name must match "
        "repro.<subsystem>.<verb> and appear in telemetry/catalog.py; "
        "dynamic names need a waiver naming the test that pins them."
    )

    def applies_to(self, ctx: LintContext) -> bool:
        # The telemetry package itself forwards caller-supplied names.
        if ctx.rel.startswith("src/repro/telemetry/"):
            return ctx.rel == ctx.config.catalog_rel
        return True

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        if ctx.rel == ctx.config.catalog_rel:
            yield from self._check_catalog(ctx)
            return
        bare = self._bare_imports(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            if not self._is_factory(node, bare):
                continue
            if not node.args:
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                yield from self._check_name(ctx, arg, arg.value)
            else:
                yield self.finding(
                    ctx,
                    arg,
                    "non-literal metric name (enumerate the names in the "
                    "catalog and waive with the reason + pinning test)",
                )

    @staticmethod
    def _bare_imports(tree: ast.Module) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module and (
                node.module == "repro.telemetry"
                or node.module.startswith("repro.telemetry.")
            ):
                for a in node.names:
                    if a.name in _TELEMETRY_FACTORIES:
                        out.add(a.asname or a.name)
        return out

    @staticmethod
    def _is_factory(node: ast.Call, bare: Set[str]) -> bool:
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr in _TELEMETRY_FACTORIES:
            base = _dotted(f.value)
            return base is not None and (
                base == "telemetry"
                or base.endswith(".telemetry")
                or base == "REGISTRY"
                or base.endswith("registry")
            )
        if isinstance(f, ast.Name) and f.id in bare:
            return True
        return False

    def _check_name(
        self, ctx: LintContext, node: ast.AST, name: str
    ) -> Iterator[Finding]:
        if not NAME_RE.match(name):
            yield self.finding(
                ctx,
                node,
                f"metric name {name!r} does not match repro.<subsystem>.<verb>",
            )
            return
        if ctx.rel.startswith("tests/") and name.startswith("repro.test."):
            return  # scratch names for registry mechanics tests
        if ctx.config.catalog and name not in ctx.config.catalog:
            yield self.finding(
                ctx,
                node,
                f"metric name {name!r} is not in telemetry/catalog.py "
                "(add it there, or fix the typo)",
            )

    def _check_catalog(self, ctx: LintContext) -> Iterator[Finding]:
        seen: Dict[str, int] = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Constant) and isinstance(node.value, str):
                name = node.value
                if not name.startswith("repro."):
                    continue
                if not NAME_RE.match(name):
                    yield self.finding(
                        ctx, node, f"catalog entry {name!r} violates the pattern"
                    )
                if name in seen:
                    yield self.finding(
                        ctx,
                        node,
                        f"duplicate catalog entry {name!r} "
                        f"(first at line {seen[name]})",
                    )
                else:
                    seen[name] = node.lineno


# ---------------------------------------------------------------------------
# BL003 — module-level mutable globals in concurrency-bound trees
# ---------------------------------------------------------------------------

_MUTABLE_CALLS = frozenset(
    {"list", "dict", "set", "bytearray", "deque", "defaultdict", "OrderedDict",
     "Counter"}
)


@register
class MutableGlobalRule(Rule):
    id = "BL003"
    title = "module-level mutable global in core/db/oltp"
    rationale = (
        "The worker-per-shard scale-out imports these modules into every "
        "shard worker; a module-level dict/list is cross-shard shared "
        "state with no lock.  Freeze it (tuple / frozenset / "
        "MappingProxyType) or waive with the synchronization story."
    )

    def applies_to(self, ctx: LintContext) -> bool:
        return ctx.in_tree(ctx.config.mutable_global_trees)

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for stmt in self._module_stmts(ctx.tree):
            targets: Sequence[ast.expr]
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets, value = [stmt.target], stmt.value
            else:
                continue
            if not self._mutable(value):
                continue
            for t in targets:
                if isinstance(t, ast.Name) and t.id != "__all__":
                    yield self.finding(
                        ctx,
                        stmt,
                        f"module-level mutable global {t.id!r} "
                        "(freeze it or waive with the synchronization story)",
                    )

    @staticmethod
    def _module_stmts(tree: ast.Module) -> Iterator[ast.stmt]:
        """Module body plus top-level if/try bodies (import-fallback idiom)."""
        stack: List[ast.stmt] = list(tree.body)
        while stack:
            stmt = stack.pop()
            if isinstance(stmt, (ast.If, ast.Try)):
                for body in (
                    getattr(stmt, "body", []),
                    getattr(stmt, "orelse", []),
                    getattr(stmt, "finalbody", []),
                ):
                    stack.extend(body)
                for h in getattr(stmt, "handlers", []):
                    stack.extend(h.body)
                continue
            yield stmt

    @staticmethod
    def _mutable(value: ast.expr) -> bool:
        if isinstance(value, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                              ast.DictComp, ast.SetComp)):
            return True
        if isinstance(value, ast.Call) and isinstance(value.func, ast.Name):
            return value.func.id in _MUTABLE_CALLS
        return False


# ---------------------------------------------------------------------------
# BL004 — shard-state mutation outside the designated owners
# ---------------------------------------------------------------------------

_MUTATOR_METHODS = frozenset(
    {"append", "extend", "insert", "add", "update", "pop", "popitem", "remove",
     "discard", "clear", "setdefault", "sort"}
)

_BL004_TREES = (
    "src/repro/core/",
    "src/repro/db/",
    "src/repro/oltp/",
    "src/repro/scan/",
    "src/repro/adaptive/",
    "src/repro/durability/",
)


@register
class ForeignStateMutationRule(Rule):
    id = "BL004"
    title = "mutation of another object's private state"
    rationale = (
        "CompressedTable/DiskArena/ResidencyManager internals are "
        "shard-local; once shard workers run concurrently, an out-of-owner "
        "write (store poking table._res, the scan engine bumping residency "
        "counters) races with the owner.  Mutate through a public entry "
        "point on the owner, or waive with the reason the write is safe."
    )

    def applies_to(self, ctx: LintContext) -> bool:
        return ctx.in_tree(_BL004_TREES) and (
            ctx.rel not in ctx.config.state_owner_modules
        )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for scope in _functions(ctx.tree):
            handles = self._foreign_handles(scope)
            for node in _walk_scope(scope):
                if isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = (
                        node.targets
                        if isinstance(node, ast.Assign)
                        else [node.target]
                    )
                    for t in targets:
                        chain = self._foreign_private(t, handles)
                        if chain:
                            yield self.finding(
                                ctx,
                                node,
                                f"write through foreign private state "
                                f"({chain}); add an entry point on the owner",
                            )
                elif isinstance(node, ast.Call):
                    f = node.func
                    if (
                        isinstance(f, ast.Attribute)
                        and f.attr in _MUTATOR_METHODS
                    ):
                        chain = self._foreign_private(f.value, handles)
                        if chain:
                            yield self.finding(
                                ctx,
                                node,
                                f"mutating call .{f.attr}() through foreign "
                                f"private state ({chain}); add an entry point "
                                "on the owner",
                            )

    def _foreign_handles(self, scope: ast.AST) -> Set[str]:
        """Local names bound to a foreign object's private attribute
        (``res = table._res``): writes through them are owner writes."""
        out: Set[str] = set()
        for node in _walk_scope(scope):
            if isinstance(node, ast.Assign) and self._has_foreign_private(
                node.value
            ):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        out.add(t.id)
        return out

    def _foreign_private(
        self, target: ast.AST, handles: Set[str]
    ) -> Optional[str]:
        """Dotted chain when ``target`` writes through foreign private
        state, else None."""
        node = target
        while isinstance(node, ast.Subscript):
            node = node.value
        if not isinstance(node, ast.Attribute):
            return None
        if self._has_foreign_private(node):
            return _dotted(node) or node.attr
        root = node
        while isinstance(root.value, ast.Attribute):
            root = root.value
        if isinstance(root.value, ast.Name) and root.value.id in handles:
            return _dotted(node) or node.attr
        return None

    @staticmethod
    def _has_foreign_private(expr: ast.AST) -> bool:
        for sub in ast.walk(expr):
            if (
                isinstance(sub, ast.Attribute)
                and sub.attr.startswith("_")
                and not sub.attr.startswith("__")
                and not (
                    isinstance(sub.value, ast.Name)
                    and sub.value.id in ("self", "cls")
                )
            ):
                return True
        return False


# ---------------------------------------------------------------------------
# BL005 — unguarded numpy narrowing casts
# ---------------------------------------------------------------------------

_NARROW_DTYPES = frozenset({"uint16", "int32"})

_GUARD_PAT = re.compile(
    r"0xFFFF|65535|2147483647|0x7FFF_?FFFF|iinfo|checked_astype|"
    r"np\.clip|np\.minimum|assert_fits"
)


@register
class NarrowingCastRule(Rule):
    id = "BL005"
    title = "narrowing cast without a bounds guard"
    rationale = (
        "uint16/int32 casts wrap silently (the plan-version-tag wrap bug "
        "class).  A narrowing astype/asarray needs a bounds guard in the "
        "same function, the sanitize-aware core.casts.checked_astype "
        "helper, or a waiver proving the value range statically."
    )

    # The version-tag-wrap bug class lives in the table/codec layer; the
    # Pallas kernel lowerings cast domain-bounded symbol data to int32
    # because jax mandates it, and are covered by kernel parity tests.
    _TREES = (
        "src/repro/core/",
        "src/repro/db/",
        "src/repro/oltp/",
        "src/repro/scan/",
        "src/repro/durability/",
        "src/repro/adaptive/",
    )

    def applies_to(self, ctx: LintContext) -> bool:
        return ctx.in_tree(self._TREES)

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for scope in list(_functions(ctx.tree)) + [ctx.tree]:
            guarded = bool(_GUARD_PAT.search(_source_of(ctx, scope)))
            if guarded:
                continue
            for node in _walk_scope(scope):
                dtype = self._narrow_cast(node)
                if dtype:
                    yield self.finding(
                        ctx,
                        node,
                        f"narrowing cast to {dtype} without a bounds guard "
                        "(use core.casts.checked_astype, guard, or waive "
                        "with the static range argument)",
                    )

    def _narrow_cast(self, node: ast.AST) -> Optional[str]:
        if not isinstance(node, ast.Call):
            return None
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "astype" and node.args:
            return self._narrow_dtype(node.args[0])
        dotted = _dotted(f) if isinstance(f, (ast.Attribute, ast.Name)) else None
        if dotted in ("np.asarray", "np.array", "numpy.asarray", "numpy.array"):
            src = node.args[0] if node.args else None
            if isinstance(src, (ast.List, ast.Tuple, ast.Constant)):
                return None  # literal source: range visible at the call
            for kw in node.keywords:
                if kw.arg == "dtype":
                    return self._narrow_dtype(kw.value)
            if len(node.args) >= 2:
                return self._narrow_dtype(node.args[1])
        return None

    @staticmethod
    def _narrow_dtype(e: ast.AST) -> Optional[str]:
        if isinstance(e, ast.Attribute) and e.attr in _NARROW_DTYPES:
            return e.attr
        if isinstance(e, ast.Name) and e.id in _NARROW_DTYPES:
            return e.id
        if (
            isinstance(e, ast.Constant)
            and isinstance(e.value, str)
            and e.value in _NARROW_DTYPES
        ):
            return e.value
        return None


# ---------------------------------------------------------------------------
# BL006 — bare except
# ---------------------------------------------------------------------------


@register
class BareExceptRule(Rule):
    id = "BL006"
    title = "bare except"
    rationale = (
        "A bare except swallows KeyboardInterrupt/SystemExit and turns "
        "poisoned-state bugs into silent data corruption; name the "
        "exception types the handler can actually recover from."
    )

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                yield self.finding(
                    ctx, node, "bare except (name the recoverable exceptions)"
                )


# ---------------------------------------------------------------------------
# BL007 — raw wall-clock reads where the telemetry clock is required
# ---------------------------------------------------------------------------

_CLOCK_ATTRS = frozenset(
    {"time", "perf_counter", "perf_counter_ns", "monotonic", "monotonic_ns"}
)


@register
class RawClockRule(Rule):
    id = "BL007"
    title = "raw time.* read in a telemetry-clocked module"
    rationale = (
        "Hot-path timing goes through telemetry.clock()/observe_since so "
        "disabled mode stays zero-cost and phase attribution sees every "
        "sample; a raw time.time() is invisible to the breakdown and "
        "keeps costing syscalls when telemetry is off."
    )

    def applies_to(self, ctx: LintContext) -> bool:
        return ctx.in_tree(ctx.config.clocked_trees)

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in _CLOCK_ATTRS
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "time"
            ):
                yield self.finding(
                    ctx,
                    node,
                    f"time.{node.func.attr}() bypasses the telemetry clock "
                    "(use telemetry.clock()/observe_since, or waive with "
                    "why wall time is data here)",
                )
