"""A waiver naming an unknown rule id: BL000."""

# blitzlint: waive[BL999] -- no such rule
X = 1
