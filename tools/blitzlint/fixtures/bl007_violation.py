"""BL007 violation: raw wall-clock read in a clocked tree."""

import time


def stamp():
    return time.time()
