"""BL002 clean: literal catalogued names only."""

from repro import telemetry

H = telemetry.histogram("repro.core.encode")
C = telemetry.counter("repro.core.encode.rows")
