"""BL003 violations: module-level mutable containers."""

CACHE = {}
REGISTRY = list()
NAMES = ["customer", "stock"]
