"""BL002 violations: typo'd, pattern-breaking, and non-literal names."""

from repro import telemetry

C = telemetry.counter("repro.core.enc0de")
H = telemetry.histogram("Repro.Core.Encode")


def dynamic(name):
    return telemetry.counter(f"repro.scan.{name}")
