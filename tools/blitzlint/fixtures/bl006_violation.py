"""BL006 violation: bare except."""


def risky():
    try:
        return 1
    except:
        return None
