"""A reasonless waiver: BL000, and the violation still fires."""

import time


def stamp():
    # blitzlint: waive[BL007]
    return time.time()
