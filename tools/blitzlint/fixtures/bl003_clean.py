"""BL003 clean: frozen module-level containers."""

from types import MappingProxyType

NAMES = ("customer", "stock")
KINDS = frozenset({"int", "str"})
TABLE = MappingProxyType({"customer": 1})
