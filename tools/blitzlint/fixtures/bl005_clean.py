"""BL005 clean: checked helper or an in-function bounds guard."""

import numpy as np

from repro.core.casts import checked_astype


def narrow(a):
    return checked_astype(a, np.uint16, where="fixture")


def clipped(a):
    return np.clip(a, 0, 65535).astype(np.uint16)
