"""An unused waiver: BL000 (nothing on this line to suppress)."""


def quiet():
    # blitzlint: waive[BL006] -- stale waiver left after a refactor
    return 1
