"""BL001 violation: statement loops over per-row iterables."""


def apply(rows):
    out = []
    for r in rows:
        out.append(r)
    n = len(rows)
    for i in range(n):
        out[i] = None
    return out
