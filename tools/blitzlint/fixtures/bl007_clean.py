"""BL007 clean: the telemetry clock."""

from repro import telemetry


def stamp():
    return telemetry.clock()
