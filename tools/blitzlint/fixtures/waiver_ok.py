"""A correctly waived violation: suppressed, waiver consumed."""

import time


def stamp():
    # blitzlint: waive[BL007] -- wall time is the fixture's return value
    return time.time()
