"""BL004 clean: own state plus the owner's public entry point."""


class Counter:
    def __init__(self):
        self._count = 0

    def bump(self):
        self._count += 1


def use(table):
    table.note_quarantined_rows(1)
