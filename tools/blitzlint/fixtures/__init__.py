"""Per-rule lint fixtures (exercised by tests/test_blitzlint.py only)."""
