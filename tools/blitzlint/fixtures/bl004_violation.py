"""BL004 violations: mutating another object's private state."""


def poke(table):
    table._plan_ver = 3
    res = table._res
    res.quarantined += 1
    table._pending.append(1)
