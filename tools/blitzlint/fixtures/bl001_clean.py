"""BL001 clean: comprehensions are boundary conversions, not control flow."""

import numpy as np


def apply(rows):
    arr = np.asarray([r["x"] for r in rows], dtype=np.float64)
    return float(arr.sum())
