"""BL005 violations: unguarded narrowing casts."""

import numpy as np


def narrow(a):
    return a.astype(np.uint16)


def convert(vals):
    return np.asarray(vals, dtype=np.int32)
