"""BL006 clean: named recoverable exceptions."""


def risky():
    try:
        return 1
    except ValueError:
        return None
