"""blitzlint core: AST lint framework with waivers and a rule registry.

Stdlib-only by design — the CI job runs it before any dependency install,
and a lint pass must never import the code under analysis (rules parse,
they do not execute).

Framework pieces:

* :class:`Rule` — subclass, set ``id``/``title``/``rationale``, implement
  ``check(ctx)`` yielding :class:`Finding`; decorate with :func:`register`.
* :class:`LintContext` — one parsed file: source, lines, AST, repo-relative
  path, and the shared :class:`LintConfig`.
* Waivers — ``# blitzlint: waive[BL001] -- reason`` on the flagged line or
  the line above suppresses that rule there.  The reason is mandatory and
  waivers must be *consumed*: a reasonless, unknown-rule, or unused waiver
  is itself a finding (``BL000``), so the waiver set can never rot.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

WAIVER_RE = re.compile(
    r"#\s*blitzlint:\s*waive\[(?P<ids>[A-Za-z0-9_,\s]*)\]"
    r"(?:\s*--\s*(?P<reason>.*\S))?"
)

NAME_RE = re.compile(r"^repro\.[a-z0-9_]+(\.[a-z0-9_]+)+$")

EXCLUDED_DIR_NAMES = frozenset({".git", "__pycache__", ".ruff_cache", ".mypy_cache"})

# The linter's own sources embed waiver syntax as string data and the
# fixtures violate rules on purpose; neither belongs in a repo sweep
# (the package is covered by tests/test_blitzlint.py instead).
EXCLUDED_RELS = ("tools/blitzlint/",)


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str  # repo-relative posix path
    line: int
    col: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclasses.dataclass
class Waiver:
    line: int
    rules: Tuple[str, ...]
    reason: str
    used: bool = False


@dataclasses.dataclass(frozen=True)
class LintConfig:
    """Repo-specific rule scoping (paths are repo-relative posix)."""

    # Modules on the sub-microsecond OLTP path: per-row Python loops here
    # are the loops the paper's batched fast path exists to eliminate.
    hot_modules: Tuple[str, ...] = (
        "src/repro/core/plan.py",
        "src/repro/core/blitzcrank.py",
        "src/repro/scan/engine.py",
        "src/repro/oltp/store.py",
    )
    # Trees that the worker-per-shard scale-out will run concurrently:
    # module-level mutable containers there are cross-shard shared state.
    mutable_global_trees: Tuple[str, ...] = (
        "src/repro/core/",
        "src/repro/db/",
        "src/repro/oltp/",
    )
    # Modules allowed to mutate CompressedTable/DiskArena internals
    # directly (the shard-local owners).  Everyone else goes through
    # public entry points.
    state_owner_modules: Tuple[str, ...] = (
        "src/repro/core/blitzcrank.py",
        "src/repro/core/arena.py",
    )
    # Trees where wall-clock reads must go through the telemetry clock
    # (so disabled-mode stays zero-cost and phase attribution stays
    # consistent).  The telemetry package itself implements the clock.
    clocked_trees: Tuple[str, ...] = (
        "src/repro/core/",
        "src/repro/db/",
        "src/repro/oltp/",
        "src/repro/scan/",
        "src/repro/durability/",
        "src/repro/adaptive/",
        "src/repro/kernels/",
    )
    catalog_rel: str = "src/repro/telemetry/catalog.py"
    catalog: Tuple[str, ...] = ()


class LintContext:
    """One file under analysis plus the shared config."""

    def __init__(
        self, path: pathlib.Path, rel: str, source: str, config: LintConfig
    ) -> None:
        self.path = path
        self.rel = rel
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=str(path))
        self.config = config

    def in_tree(self, trees: Sequence[str]) -> bool:
        return any(self.rel.startswith(t) for t in trees)


class Rule:
    """Base class; subclasses register themselves via :func:`register`."""

    id: str = "BL000"
    title: str = ""
    rationale: str = ""

    def applies_to(self, ctx: LintContext) -> bool:
        return True

    def check(self, ctx: LintContext) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, ctx: LintContext, node: ast.AST, message: str) -> Finding:
        return Finding(
            self.id,
            ctx.rel,
            getattr(node, "lineno", 1),
            getattr(node, "col_offset", 0) + 1,
            message,
        )


RULES: Dict[str, Rule] = {}


def register(cls: type) -> type:
    inst = cls()
    if inst.id in RULES:
        raise ValueError(f"duplicate rule id {inst.id}")
    RULES[inst.id] = inst
    return cls


def parse_waivers(lines: Sequence[str]) -> Tuple[List[Waiver], List[Finding]]:
    """Extract waiver comments; malformed ones become BL000 findings
    immediately (they can never suppress anything)."""
    waivers: List[Waiver] = []
    bad: List[Finding] = []
    for i, text in enumerate(lines, start=1):
        m = WAIVER_RE.search(text)
        if not m:
            if "blitzlint:" in text and "waive" in text:
                bad.append(
                    Finding("BL000", "", i, 1, "malformed blitzlint waiver comment")
                )
            continue
        ids = tuple(s.strip() for s in m.group("ids").split(",") if s.strip())
        reason = (m.group("reason") or "").strip()
        if not ids:
            bad.append(Finding("BL000", "", i, 1, "waiver names no rule ids"))
            continue
        unknown = [r for r in ids if r not in RULES]
        if unknown:
            bad.append(
                Finding("BL000", "", i, 1, f"waiver names unknown rules: {unknown}")
            )
        if not reason:
            bad.append(
                Finding(
                    "BL000",
                    "",
                    i,
                    1,
                    f"waiver for {list(ids)} has no reason "
                    "(syntax: # blitzlint: waive[BLxxx] -- why)",
                )
            )
            continue
        waivers.append(Waiver(i, ids, reason))
    return waivers, bad


def apply_waivers(
    findings: List[Finding], waivers: List[Waiver], rel: str
) -> List[Finding]:
    """Drop findings covered by a waiver on the same or preceding line;
    flag waivers that covered nothing."""
    kept: List[Finding] = []
    for f in findings:
        suppressed = False
        for w in waivers:
            if f.rule in w.rules and w.line in (f.line, f.line - 1):
                w.used = True
                suppressed = True
        if not suppressed:
            kept.append(f)
    for w in waivers:
        if not w.used:
            kept.append(
                Finding(
                    "BL000",
                    rel,
                    w.line,
                    1,
                    f"unused waiver for {list(w.rules)} (nothing to suppress)",
                )
            )
    kept.sort(key=lambda f: (f.line, f.col, f.rule))
    return kept


def lint_source(
    source: str,
    rel: str,
    config: LintConfig,
    path: Optional[pathlib.Path] = None,
    rules: Optional[Sequence[Rule]] = None,
) -> List[Finding]:
    ctx = LintContext(path or pathlib.Path(rel), rel, source, config)
    active = list(rules) if rules is not None else list(RULES.values())
    raw: List[Finding] = []
    for rule in active:
        if rule.applies_to(ctx):
            raw.extend(rule.check(ctx))
    waivers, bad = parse_waivers(ctx.lines)
    out = apply_waivers(raw, waivers, rel)
    out.extend(dataclasses.replace(b, path=rel) for b in bad)
    out.sort(key=lambda f: (f.line, f.col, f.rule))
    return out


def lint_file(
    path: pathlib.Path,
    root: pathlib.Path,
    config: LintConfig,
    rules: Optional[Sequence[Rule]] = None,
) -> List[Finding]:
    rel = path.resolve().relative_to(root.resolve()).as_posix()
    return lint_source(path.read_text(), rel, config, path=path, rules=rules)


def iter_python_files(
    paths: Iterable[pathlib.Path], root: pathlib.Path
) -> Iterator[pathlib.Path]:
    seen = set()
    rroot = root.resolve()
    for p in paths:
        cands = [p] if p.is_file() else sorted(p.rglob("*.py"))
        for f in cands:
            rf = f.resolve()
            rel = rf.relative_to(rroot).as_posix()
            if rf in seen or any(rel.startswith(e) for e in EXCLUDED_RELS):
                continue
            if any(part in EXCLUDED_DIR_NAMES for part in rf.parts):
                continue
            seen.add(rf)
            yield f


def load_catalog(root: pathlib.Path, catalog_rel: str) -> Tuple[str, ...]:
    """Read METRICS from the catalog module *without importing it* — the
    lint job must not require the library's dependencies."""
    path = root / catalog_rel
    if not path.exists():
        return ()
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        targets: List[ast.expr] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
            value = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
            value = node.value
        else:
            continue
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "METRICS":
                names = ast.literal_eval(value)
                return tuple(str(n) for n in names)
    return ()


def make_config(root: pathlib.Path) -> LintConfig:
    cfg = LintConfig()
    return dataclasses.replace(cfg, catalog=load_catalog(root, cfg.catalog_rel))


def lint_paths(
    paths: Sequence[pathlib.Path],
    root: pathlib.Path,
    config: Optional[LintConfig] = None,
    rules: Optional[Sequence[Rule]] = None,
) -> List[Finding]:
    cfg = config or make_config(root)
    out: List[Finding] = []
    for f in iter_python_files(paths, root):
        out.extend(lint_file(f, root, cfg, rules=rules))
    return out
