"""CLI: ``python -m tools.blitzlint [paths...]`` — exit 1 on findings."""

from __future__ import annotations

import argparse
import pathlib
import sys
from typing import List

from . import RULES, lint_paths

DEFAULT_PATHS = ["src", "tools", "tests", "benchmarks", "examples"]


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="blitzlint")
    ap.add_argument("paths", nargs="*", default=None)
    ap.add_argument(
        "--root",
        default=".",
        help="repo root (catalog + relative paths resolve against it)",
    )
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULES.values(), key=lambda r: r.id):
            print(f"{rule.id}  {rule.title}")
            print(f"       {rule.rationale}")
        return 0

    root = pathlib.Path(args.root)
    paths = [
        root / p for p in (args.paths or DEFAULT_PATHS) if (root / p).exists()
    ]
    findings = lint_paths(paths, root)
    for f in findings:
        print(f.render())
    if findings:
        print(f"blitzlint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
