"""blitzlint: repo-invariant static analysis for the Blitzcrank repro.

Usage::

    python -m tools.blitzlint            # lint the default path set
    python -m tools.blitzlint src tests  # lint specific paths
    python -m tools.blitzlint --list-rules

Rules are registered on import of :mod:`tools.blitzlint.rules`; the
catalog of rule ids, rationales, and the waiver syntax lives in
DESIGN.md §10.
"""

from . import rules as _rules  # noqa: F401  (registers the rule set)
from .core import (
    Finding,
    LintConfig,
    LintContext,
    RULES,
    Rule,
    lint_file,
    lint_paths,
    lint_source,
    load_catalog,
    make_config,
)

__all__ = [
    "Finding",
    "LintConfig",
    "LintContext",
    "RULES",
    "Rule",
    "lint_file",
    "lint_paths",
    "lint_source",
    "load_catalog",
    "make_config",
]
