"""OLTP scenario (paper §6/§7): an in-memory row store under a YCSB-style
mixed workload, comparing Blitzcrank against zstd / Raman / uncompressed,
with the §6.5 LRU fast path for read-modify-write transactions.

Run:  PYTHONPATH=src python examples/oltp_store.py
"""

import time

import numpy as np

from repro.oltp import tpcc
from repro.oltp.store import (BlitzStore, LRUFastPath, RamanStore,
                              UncompressedStore, ZstdStore)


def main(n_rows=4000, n_reads=1500, n_rmw=500):
    schema, gen = tpcc.TABLES["customer"]
    rows = gen(n_rows)
    raw = tpcc.row_bytes(rows)
    rng = np.random.default_rng(0)
    zipf_keys = (rng.zipf(1.2, 8 * n_reads) - 1)
    zipf_keys = zipf_keys[zipf_keys < n_rows]

    print(f"{'store':12s} {'factor':>7s} {'read us':>9s} {'rmw us':>9s} "
          f"{'hit%':>6s}")
    for cls in (UncompressedStore, ZstdStore, RamanStore, BlitzStore):
        try:
            store = cls(schema, rows[: n_rows // 2])
        except ImportError:  # optional backend (zstandard) not installed
            continue
        for r in rows:
            store.insert(r)

        t0 = time.perf_counter()
        for i in zipf_keys[:n_reads]:
            store.get(int(i))
        t_read = (time.perf_counter() - t0) / n_reads

        fp = LRUFastPath(store, capacity=256)
        t0 = time.perf_counter()
        for i in zipf_keys[n_reads:n_reads + n_rmw]:
            fp.read_modify_write(int(i),
                                 lambda r: r.update(c_balance=r["c_balance"] + 1))
        t_rmw = (time.perf_counter() - t0) / n_rmw
        hit = fp.hits / max(fp.hits + fp.misses, 1)
        print(f"{store.name:12s} {raw / store.nbytes:7.2f} "
              f"{1e6 * t_read:9.1f} {1e6 * t_rmw:9.1f} {100 * hit:6.1f}")

    print("\nBlitzcrank: highest factor; the fast path absorbs Zipfian "
          "updates (paper Fig. 13).")


if __name__ == "__main__":
    main()
