"""OLTP scenario (paper §6/§7): an in-memory row store under a YCSB-style
mixed workload, comparing Blitzcrank against zstd / Raman / uncompressed
through the unified batched RowStore protocol (DESIGN.md §3), with the
§6.5 LRU fast path for read-modify-write transactions.

Run:  PYTHONPATH=src python examples/oltp_store.py
      PYTHONPATH=src python examples/oltp_store.py --mix   # update-heavy
                                                           # TPC-C mix with
                                                           # delta-merge stats
      PYTHONPATH=src python examples/oltp_store.py --drift # drifting mix:
                                                           # adaptive refit
                                                           # on vs off
      PYTHONPATH=src python examples/oltp_store.py --db    # full multi-table
                                                           # TPC-C through the
                                                           # repro.db engine
      PYTHONPATH=src python examples/oltp_store.py --budget # out-of-core
                                                           # cold tier under a
                                                           # memory budget
      PYTHONPATH=src python examples/oltp_store.py --durable # WAL + checkpoint:
                                                           # close, reopen,
                                                           # verify recovery
      PYTHONPATH=src python examples/oltp_store.py --crash-demo # kill the
                                                           # process at a crash
                                                           # point, recover
      PYTHONPATH=src python examples/oltp_store.py --metrics # telemetry
                                                           # snapshot: counters,
                                                           # percentiles, phase
                                                           # breakdown (§9)
"""

import argparse
import os
import shutil
import tempfile
import time

import numpy as np

from repro.adaptive import DriftConfig, MaintenanceConfig
from repro.oltp import tpcc
from repro.oltp.store import (BlitzStore, LRUFastPath, RamanStore,
                              UncompressedStore, ZstdStore)


def compare_stores(n_rows=4000, n_reads=1500, n_rmw=500):
    schema, gen = tpcc.TABLES["customer"]
    rows = gen(n_rows)
    raw = tpcc.row_bytes(rows)
    rng = np.random.default_rng(0)
    read_keys = tpcc.zipf_keys(rng, n_rows, n_reads, a=1.2)
    rmw_keys = tpcc.zipf_keys(rng, n_rows, n_rmw, a=1.2)

    print(f"{'store':12s} {'factor':>7s} {'read us':>9s} {'rmw us':>9s} "
          f"{'hit%':>6s}")
    for cls in (UncompressedStore, ZstdStore, RamanStore, BlitzStore):
        try:
            store = cls(schema, rows[: n_rows // 2])
        except ImportError:  # optional backend (zstandard) not installed
            continue
        store.insert_many(rows)

        t0 = time.perf_counter()
        tpcc.batched_point_gets(store, read_keys, batch=256)
        t_read = (time.perf_counter() - t0) / n_reads

        fp = LRUFastPath(store, capacity=256)
        t0 = time.perf_counter()
        for i in rmw_keys:
            fp.read_modify_write(int(i),
                                 lambda r: r.update(c_balance=r["c_balance"] + 1))
        t_rmw = (time.perf_counter() - t0) / n_rmw
        fp.sync()
        hit = fp.hits / max(fp.hits + fp.misses, 1)
        print(f"{store.name:12s} {raw / store.nbytes:7.2f} "
              f"{1e6 * t_read:9.1f} {1e6 * t_rmw:9.1f} {100 * hit:6.1f}")

    print("\nBlitzcrank: highest factor; the fast path absorbs Zipfian "
          "updates (paper Fig. 13).")


def update_heavy_mix(n_rows=8000, n_ops=30000):
    """Payment-heavy TPC-C mix: the delta overlay merges back into the
    arena instead of growing forever (DESIGN.md §3)."""
    schema, gen = tpcc.TABLES["customer"]
    rows = gen(n_rows)
    store = BlitzStore(schema, rows, sample=1 << 13)
    store.insert_many(rows)
    post_load = store.stats()
    print(f"loaded {post_load['n_live']} rows, "
          f"{post_load['nbytes'] / 1024:.0f} KiB compressed "
          f"(factor {tpcc.row_bytes(rows) / post_load['nbytes']:.2f})")

    t0 = time.perf_counter()
    counts = tpcc.run_transaction_mix(
        store, n_ops, seed=3, p_payment=0.6, p_order_status=0.25,
        p_new_order=0.10, p_delivery=0.05, new_row_fn=tpcc.customer_row)
    dt = time.perf_counter() - t0
    s = store.stats()
    print(f"\n{n_ops} ops in {dt:.1f}s "
          f"({1e6 * dt / n_ops:.1f} us/op): {counts}")
    print(f"bytes: total {s['nbytes'] / 1024:.0f} KiB "
          f"(= {s['nbytes'] / post_load['nbytes']:.2f}x post-load) | "
          f"arena {s['arena_bytes'] / 1024:.0f} KiB, "
          f"overlay {s['overlay_bytes'] / 1024:.1f} KiB "
          f"({s['overlay_rows']} rows), dead {s['dead_bytes'] / 1024:.1f} KiB")
    print(f"compaction: {s['merges']} merges, {s['rewrites']} arena "
          f"rewrites; live rows {s['n_live']} (+{counts['inserts']} inserted, "
          f"-{counts['deletes']} deleted)")
    escapes = {k: v for k, v in s["escapes"].items() if v}
    print(f"escape counters (refit hook): {escapes}")


def drifting_mix(n_rows=5000, n_ops=50000):
    """Drifting TPC-C mix (DESIGN.md §4): over the run, new customers carry
    previously unseen names/cities/employers and widening balances.  With
    adaptive maintenance off the fitted models degrade toward raw size;
    with it on, drift detection + background refit + plan-version migration
    hold the compression factor."""
    schema, gen = tpcc.TABLES["customer"]
    rows = gen(n_rows)
    maint = MaintenanceConfig(
        drift=DriftConfig(rate_threshold=0.02, min_escapes=32,
                          min_window_rows=256),
        check_every=1024, migrate_rows_per_step=2048, numeric_headroom=2.0)
    for label, adaptive in (("refit off", False), ("refit on ", maint)):
        store = BlitzStore(schema, rows, sample=1 << 13,
                           merge_min_bytes=1 << 14, adaptive=adaptive)
        store.insert_many(rows)
        t0 = time.perf_counter()
        tpcc.run_transaction_mix(
            store, n_ops, seed=3, p_payment=0.25, p_order_status=0.15,
            p_new_order=0.55, p_delivery=0.05,
            new_row_fn=tpcc.drifting_customer_row, drift=1.0)
        dt = time.perf_counter() - t0
        s = store.stats()
        raw = tpcc.row_bytes([r for _, r in store.scan()])
        line = (f"{label}: factor {raw / s['nbytes']:.2f} "
                f"({s['nbytes'] / 1024:.0f} KiB for {s['n_live']} rows, "
                f"{1e6 * dt / n_ops:.0f} us/op)")
        if s.get("maintenance"):
            m = s["maintenance"]
            line += (f" | {m['refits']} refits -> {s['plan_versions']} plan "
                     f"versions, {s['migrated_rows']} rows migrated, "
                     f"frozen: {m['frozen_columns']}")
        print(line)
    print("\nRefit-on holds the compression factor as the workload drifts "
          "(paper §5 dynamic value sets; BENCH_adaptive_refit.json).")


def multi_table_db(n_ops=1500):
    """Full multi-table TPC-C through the repro.db engine (DESIGN.md §5):
    seven hash-partitioned tables in one Database catalog, the cross-table
    NewOrder/Payment/OrderStatus/Delivery mix, and the whole-database
    compression factor the paper's §6 is about."""
    print("loading the 7-table TPC-C database (blitzcrank vs silo)...")
    db, pop = tpcc.build_tpcc_database(
        backend="blitzcrank", n_shards=4, n_warehouses=2,
        districts_per_wh=10, customers_per_district=150, n_items=1000,
        orders_per_district=50)
    silo, _ = tpcc.build_tpcc_database(backend="silo", n_shards=4,
                                       population=pop)
    print(f"loaded {db.n_live} rows across {len(db)} tables; "
          f"post-load factor {silo.nbytes / db.nbytes:.2f}x")

    t0 = time.perf_counter()
    counts = tpcc.run_tpcc_mix(db, n_ops, seed=7)
    dt = time.perf_counter() - t0
    tpcc.run_tpcc_mix(silo, n_ops, seed=7)
    db.merge_all()
    print(f"\n{n_ops} transactions in {dt:.1f}s "
          f"({1e6 * dt / n_ops:.0f} us/txn): {counts}")
    s, ss = db.stats(), silo.stats()
    print(f"{'table':11s} {'rows':>7s} {'blitz KiB':>10s} {'silo KiB':>9s} "
          f"{'factor':>7s} {'shards':>7s}")
    for name in db.table_names:
        ts, tss = s["tables"][name], ss["tables"][name]
        print(f"{name:11s} {ts['n_live']:7d} {ts['nbytes'] / 1024:10.1f} "
              f"{tss['nbytes'] / 1024:9.1f} "
              f"{tss['nbytes'] / ts['nbytes']:7.2f} {ts['n_shards']:7d}")
    print(f"{'TOTAL':11s} {s['n_live']:7d} {s['nbytes'] / 1024:10.1f} "
          f"{ss['nbytes'] / 1024:9.1f} {ss['nbytes'] / s['nbytes']:7.2f}")
    print(f"\nwhole-database factor {ss['nbytes'] / s['nbytes']:.2f}x "
          f"(models {s['model_bytes'] / 1024:.0f} KiB reported separately); "
          "see BENCH_db_tpcc.json for the acceptance run.")


def out_of_core(budget_frac=0.25, n_ops=2000):
    """Cold-tier demo (paper §6.4, DESIGN.md §6): cap the blitz store at a
    fraction of its fully-resident size and watch cold blocks spill to
    disk and fault back in while reads stay bit-identical."""
    schema, gen = tpcc.TABLES["customer"]
    rows = gen(6000)
    ref = BlitzStore(schema, rows, sample=1 << 13)
    ref.insert_many(rows)
    budget = int(budget_frac * ref.stats()["nbytes"])
    store = BlitzStore(schema, rows, sample=1 << 13, memory_budget=budget)
    store.insert_many(rows)
    t0 = time.perf_counter()
    tpcc.run_transaction_mix(store, n_ops, seed=5)
    dt = time.perf_counter() - t0
    tpcc.run_transaction_mix(ref, n_ops, seed=5)  # same ops, uncapped
    store.merge()
    ref.merge()
    s = store.stats()
    res = s["residency"]
    print(f"budget {budget / 1024:.0f} KiB "
          f"({budget_frac:.0%} of the resident store)")
    print(f"resident {s['nbytes'] / 1024:.0f} KiB (arena + overlay + "
          f"metadata), spilled {s['spilled_bytes'] / 1024:.0f} KiB on disk "
          f"({res['spilled_blocks']} blocks)")
    print(f"{n_ops} zipfian ops in {dt:.2f}s: {res['faults']} faults in "
          f"{res['fault_batches']} grouped reads, {res['spills']} spills")
    probe = list(range(0, len(rows), 7))
    ok = store.get_many(probe) == ref.get_many(probe)
    print(f"reads bit-identical to the uncapped store: {ok}; "
          "see BENCH_out_of_core.json for the Fig. 15-style run.")


def durable(n_rows=3000, n_ops=800):
    """Durability demo (DESIGN.md §7): run a TPC-C mix against a durable
    database (per-table WAL + checksummed spill pages), close it with a
    checkpoint, reopen from disk, and verify the recovered reads are
    bit-identical."""
    from repro.db import Database, TableSchema

    root = tempfile.mkdtemp(prefix="oltp_durable_")
    try:
        schema, gen = tpcc.TABLES["customer"]
        rows = gen(n_rows)
        db = Database(backend="blitzcrank", memory_budget=64 * 1024,
                      durability=root)
        table = db.create_table(TableSchema("customer", schema, "c_id"),
                                sample_rows=rows[: n_rows // 2])
        table.insert_many(rows)
        # keyed table: NewOrder ids must be fresh, not len(store)-based
        next_id = iter(range(n_rows, n_rows + n_ops))

        t0 = time.perf_counter()
        tpcc.run_transaction_mix(
            table, n_ops, seed=5,
            new_row_fn=lambda rng, _i: tpcc.customer_row(rng, next(next_id)))
        dt = time.perf_counter() - t0
        wal_kib = os.path.getsize(os.path.join(root, "customer.wal")) / 1024
        print(f"{n_ops} ops in {dt:.2f}s against the durable store "
              f"(WAL {wal_kib:.0f} KiB, fsync per batch)")
        keys = [k for k, _ in table.scan()]
        want = table.get_many(keys)
        db.close()  # final checkpoint: codecs + block index + residency
        ckpt_kib = os.path.getsize(os.path.join(root, "checkpoint.bin")) / 1024

        t0 = time.perf_counter()
        rdb = Database.open(root)
        dt = time.perf_counter() - t0
        ok = rdb["customer"].get_many(keys) == want
        print(f"reopened from checkpoint ({ckpt_kib:.0f} KiB) in {dt:.2f}s; "
              f"{len(keys)} recovered reads bit-identical: {ok}")
        rdb.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)


def crash_demo(point="apply.before"):
    """Fault-injection demo (DESIGN.md §7): arm a named crash point so the
    simulated process dies mid-operation, then recover from WAL +
    checkpoint and verify against an uncrashed reference."""
    from repro.durability import harness

    print(f"arming crash point {point!r} (one of {len(harness.CRASH_POINTS)}"
          " named points; the CI recovery-matrix job sweeps them all)...")
    r = harness.run_crash_scenario(point, backend="blitzcrank", seed=0)
    state = "crashed mid-run" if r["crashed"] else "never crashed"
    print(f"workload {state} after {r['applied']} applied batches; "
          f"recovery must replay {r.get('expected_batches', '?')} from "
          "checkpoint + WAL tail")
    verdict = "bit-identical" if r["ok"] else f"MISMATCH: {r['errors']}"
    print(f"recovered database vs uncrashed reference: {verdict}")

    print("\ninjecting a bit flip into a spilled page: the CRC frame "
          "catches it and the row is rebuilt from the WAL")
    errs = harness._scenario_spill_bitflip(0)
    print("spill corruption repaired, reads clean:", not errs)


def metrics_demo(n_ops=1200, json_path=None):
    """Telemetry demo (DESIGN.md §9): run a short multi-table TPC-C mix
    with the always-on instrumentation, then pretty-print the registry —
    top counters, latency percentiles per hot path, and the per-phase
    wall-time breakdown that locates the OLTP speed gap."""
    import json as _json

    from repro import telemetry

    telemetry.reset()
    db, _ = tpcc.build_tpcc_database(
        backend="blitzcrank", n_shards=2, n_warehouses=2,
        districts_per_wh=4, customers_per_district=80, n_items=400,
        orders_per_district=20)
    base = telemetry.REGISTRY.hist_seconds()
    t0 = time.perf_counter()
    counts = tpcc.run_tpcc_mix(db, n_ops, seed=11)
    wall = time.perf_counter() - t0
    print(f"{n_ops} transactions in {wall:.2f}s "
          f"({1e6 * wall / n_ops:.0f} us/txn): {counts}\n")

    snap = telemetry.snapshot()
    top = sorted(snap["counters"].items(), key=lambda kv: -kv[1])[:12]
    print(f"{'counter':40s} {'value':>12s}")
    for name, value in top:
        print(f"{name:40s} {value:12d}")

    print(f"\n{'histogram':40s} {'count':>8s} {'p50 us':>9s} "
          f"{'p95 us':>9s} {'p99 us':>9s}")
    hists = sorted(snap["histograms"].items(),
                   key=lambda kv: -kv[1]["total_s"])[:12]
    for name, h in hists:
        print(f"{name:40s} {h['count']:8d} {h['p50_us']:9.1f} "
              f"{h['p95_us']:9.1f} {h['p99_us']:9.1f}")

    bd = telemetry.phase_breakdown(wall, since=base)
    print(f"\nper-phase breakdown of the mix "
          f"(coverage {bd['coverage']:.2f}):")
    for phase, frac in sorted(bd["phase_frac"].items(),
                              key=lambda kv: -kv[1]):
        bar = "#" * int(50 * frac)
        print(f"  {phase:12s} {100 * frac:5.1f}%  {bar}")
    print("\npython_glue is interpreter time between instrumented "
          "kernels — the 7.5x-gap residual (DESIGN.md §9.4).")

    if json_path:
        doc = dict(snap, phases=bd)
        with open(json_path, "w") as f:
            _json.dump(doc, f, indent=2, sort_keys=True)
        print(f"full snapshot written to {json_path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mix", action="store_true",
                    help="run the update-heavy TPC-C transaction mix "
                         "with delta-merge stats")
    ap.add_argument("--drift", action="store_true",
                    help="drifting TPC-C mix over 50k ops: adaptive "
                         "refit on vs off compression factor")
    ap.add_argument("--db", action="store_true",
                    help="full multi-table TPC-C through the repro.db "
                         "engine (catalog + hash-partitioned shards)")
    ap.add_argument("--budget", action="store_true",
                    help="out-of-core cold tier: spill/fault under a "
                         "memory budget (DESIGN.md §6)")
    ap.add_argument("--durable", action="store_true",
                    help="WAL + checkpoint: run a mix durably, close, "
                         "reopen, verify bit-identical recovery (§7)")
    ap.add_argument("--crash-demo", action="store_true",
                    help="fault injection: kill at a named crash point, "
                         "recover, verify against a reference (§7)")
    ap.add_argument("--metrics", action="store_true",
                    help="short TPC-C mix + telemetry snapshot: top "
                         "counters, latency percentiles, phase "
                         "breakdown (DESIGN.md §9)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="with --metrics: also write the full telemetry "
                         "snapshot as JSON")
    args = ap.parse_args()
    if args.metrics:
        metrics_demo(json_path=args.json)
    elif args.crash_demo:
        crash_demo()
    elif args.durable:
        durable()
    elif args.budget:
        out_of_core()
    elif args.db:
        multi_table_db()
    elif args.drift:
        drifting_mix()
    elif args.mix:
        update_heavy_mix()
    else:
        compare_stores()


if __name__ == "__main__":
    main()
