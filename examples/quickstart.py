"""Quickstart: Blitzcrank semantic compression in five minutes.

Fits semantic models on a table, compresses rows with delayed coding,
reads one tuple back at random-access granularity, and shows the three
decode paths (reference / vectorized numpy / Pallas kernel oracle).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

from repro.core import CompressedTable, TableCodec
from repro.core.coders import DiscreteCoder, quantize_freqs
from repro.core.vectorized import decode_batch, encode_batch
from repro.oltp import tpcc


def main():
    # ------------------------------------------------------------------
    # 1. A TPC-C-like customer table (Table 2 generation methods)
    rows = tpcc.gen_customer(5000)
    schema = tpcc.CUSTOMER_SCHEMA
    raw = tpcc.row_bytes(rows)

    # 2. Fit: Semantic Learner (structure learning + model generation)
    codec = TableCodec.fit(rows, schema, correlation=True, sample=2048)
    print(f"column order: {codec.stats.order}")
    print("learned parents: "
          f"{ {k: v for k, v in codec.stats.parents.items() if v} }")
    print(f"model size: {codec.model_bytes() / 1024:.0f} KiB, "
          f"fit time: {codec.stats.structuring_s + codec.stats.generation_s:.2f}s")

    # 3. Compress every row at single-tuple granularity (§6.4 default)
    table = CompressedTable(codec)
    for r in rows:
        table.append(r)
    table.flush()
    print(f"compressed {len(table)} rows: {table.nbytes / 1024:.0f} KiB "
          f"vs raw {raw / 1024:.0f} KiB -> factor {raw / table.nbytes:.2f}x")

    # 4. Random access: decompress one tuple (the OLTP point query)
    t0 = time.perf_counter()
    row = table.get(4321)
    dt = time.perf_counter() - t0
    print(f"row 4321 ({1e6 * dt:.0f} us): {row['c_first']} @ "
          f"{row['c_street']}, {row['c_city']}")
    assert row["c_first"] == rows[4321]["c_first"]

    # 5. Unseen values still compress (semantic models, not dictionaries)
    new = dict(rows[0])
    new.update(c_first="Blitzcrank", c_city=rows[0]["c_city"])
    codes = codec.compress_block([new])
    back = codec.decompress_block(codes, 1)[0]
    assert back["c_first"] == "Blitzcrank"
    print(f"unseen value round-trip OK ({2 * codes.size} bytes)")

    # 6. The TPU-layout batched decoder (and its Pallas kernel twin)
    w = 1.0 / np.arange(1, 257) ** 1.2
    coder = DiscreteCoder(quantize_freqs(w * 1e6))
    syms = np.random.default_rng(0).integers(0, 256, size=(4096, 16))
    codes, offsets = encode_batch(syms, [coder] * 16)
    t0 = time.perf_counter()
    out = decode_batch(codes, offsets, [coder] * 16)
    dt = time.perf_counter() - t0
    assert (out == syms).all()
    print(f"batched delayed decode: {1e9 * dt / syms.size:.1f} ns/symbol "
          f"({16 * codes.size / syms.size:.2f} bits/symbol)")


if __name__ == "__main__":
    main()
