"""End-to-end driver: serve a small LM with batched requests (deliverable b).

Builds a reduced gemma2-family model, trains it briefly on the synthetic
Markov stream so generations are non-trivial, then serves a request batch:
prefill -> greedy decode with the paged KV cache (write tail + flushes),
offloading cold KV pages to the Blitzcrank-compressed host store — the
paper's larger-than-memory flow (§7.2) at serving time.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import time

import numpy as np

import jax

from repro.configs import reduced_config
from repro.launch.mesh import make_host_mesh
from repro.models.config import ShapeConfig
from repro.serve.engine import Engine
from repro.train.loop import Trainer, TrainerConfig


def main():
    arch = "gemma2-9b"
    cfg = reduced_config(arch)

    # --- brief training so the model predicts the synthetic Markov shift ---
    shape = ShapeConfig("serve-demo", seq_len=64, global_batch=8, kind="train")
    tc = TrainerConfig(arch=arch, steps=60, log_every=20)
    tr = Trainer(tc, make_host_mesh(), cfg=cfg, shape=shape)
    out = tr.run(resume=False)
    print("train:", [f"step {m['step']}: loss {m['loss']:.2f}"
                     for m in tr.metrics_log])

    # --- serve a batch of requests ---
    eng = Engine(cfg, out["params"], max_len=128, donate=False)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, min(cfg.vocab, 32768), size=(8, 24)).astype(np.int32)
    t0 = time.perf_counter()
    res = eng.generate(prompts, max_new=48, temperature=0.0)
    dt = time.perf_counter() - t0
    toks = 8 * 48
    print(f"served 8 requests x 48 tokens in {dt:.2f}s "
          f"({1e3 * dt / toks:.1f} ms/token on CPU)")
    print("sample continuation:", res.tokens[0][:16].tolist())

    # --- offload the KV cache to the compressed host store (§7.2 flow) ---
    _, state = eng.prefill(jax.numpy.asarray(prompts))
    store = eng.offload_kv(state, page_tokens=8)
    print(f"KV offload: {len(store.pages)} pages, "
          f"{store.nbytes / 1024:.0f} KiB compressed vs "
          f"{store.raw_nbytes() / 1024:.0f} KiB raw "
          f"({store.raw_nbytes() / max(store.nbytes, 1):.2f}x)")
    k, v = store.get(0, 0)
    print(f"random page fetch OK: page(0,0) -> {k.shape}")


if __name__ == "__main__":
    main()
