"""Fault-tolerant training demo: crash injection, checkpoint restart,
compressed checkpoints, compressed example store, and the step watchdog.

Run:  PYTHONPATH=src python examples/resilient_training.py
"""

import tempfile


from repro.configs import reduced_config
from repro.data.pipeline import (CompressedExampleStore, SyntheticLM,
                                 batches_from_store)
from repro.launch.mesh import make_host_mesh
from repro.models.config import ShapeConfig
from repro.train.fault_tolerance import run_with_restarts
from repro.train.loop import Trainer, TrainerConfig


def main():
    arch = "phi4-mini-3.8b"
    cfg = reduced_config(arch)
    shape = ShapeConfig("demo", seq_len=48, global_batch=8, kind="train")

    # --- Blitzcrank-compressed host example store feeding the pipeline ---
    lm = SyntheticLM(cfg.vocab, shape.seq_len, shape.global_batch, seed=0)
    store = CompressedExampleStore(lm.batch(0)["tokens"], vocab=cfg.vocab)
    for s in range(16):
        store.extend(lm.batch(s)["tokens"])
    print(f"example store: {len(store)} rows, "
          f"{store.nbytes / 1024:.0f} KiB vs raw "
          f"{store.raw_nbytes() / 1024:.0f} KiB "
          f"({store.raw_nbytes() / store.nbytes:.2f}x)")

    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(arch=arch, steps=24, ckpt_dir=d, ckpt_every=8,
                           log_every=6, watchdog_s=300.0, compress_ckpt=True)
        mesh = make_host_mesh()

        def attempt(i):
            tr = Trainer(tc, mesh, cfg=cfg, shape=shape,
                         data=batches_from_store(store, shape.global_batch,
                                                 seed=1))
            # crash mid-run on the first attempt; resume from step-16 ckpt
            tr.run(resume=True, fail_at_step=18 if i == 0 else None)
            attempt.log = tr.metrics_log
            return True

        rep = run_with_restarts(attempt, max_restarts=2)
        print(f"completed={rep.completed} after {rep.restarts} restart(s); "
              f"errors caught: {rep.errors}")
        for m in attempt.log:
            print(f"  step {m['step']:3d}  loss {m['loss']:.3f}  "
                  f"lr {m['lr']:.2e}")


if __name__ == "__main__":
    main()
